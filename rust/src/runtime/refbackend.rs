//! Reference execution backend: a pure-rust interpreter for the artifact
//! programs, mirroring the python oracles (`python/compile/kernels/ref.py`
//! and the `model.py`/`multimodal.py` forwards) on the [`crate::tensor`]
//! substrate. No XLA, no HLO files — only the manifest's program table and
//! model configs are needed, so the whole serving/eval stack runs offline.
//!
//! Interpreted program families (names match `python/compile/aot.py`):
//!
//! * `score_<model>`        — (tokens[b,t]) → per-sequence mean NLL [b]
//! * `step_<model>`         — (tokens[b,t], lens[b]) → next-token logits
//! * `latent_score_<tag>`   — MLA architecture scoring (factored weights)
//! * `latent_step_<tag>`    — MLA architecture decode step
//! * `mm_score_<name>`      — (images[b,16,16], tokens[b,l]) → answer logits
//!
//! Numerics: f64 end to end (the substrate's dtype); the python programs
//! run f32, so agreement is to f32 round-off, well inside the goldens'
//! cross-check tolerance.

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{Backend, DecodeSession, Executable, ProgramCtx};
use super::decode::{CacheKind, DecodeState, LayerCache, PrefixSnapshot};
use super::literal::ParamValue;
use super::profile;
use crate::model::io::Tensor;
use crate::model::Weights;
use crate::tensor::{Layout, PackedMat};
use crate::util::json::Value;
use crate::Matrix;

/// The default backend: interprets programs directly.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefBackend;

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn compile(&self, ctx: &ProgramCtx) -> Result<Box<dyn Executable>> {
        let kind = parse_program(ctx.name, ctx.manifest)
            .with_context(|| format!("ref backend: program {:?}", ctx.name))?;
        Ok(Box::new(RefExecutable {
            kind,
            cache: std::sync::Mutex::new(ModelCache::new()),
        }))
    }
}

// ---------------------------------------------------------------------------
// Program resolution from the manifest
// ---------------------------------------------------------------------------

/// Transformer dims the interpreter needs (factor ranks and layer shapes
/// are read off the weight tensors at execution time).
#[derive(Clone, Debug)]
struct LmCfg {
    vocab: usize,
    d: usize,
    n_layers: usize,
    n_heads: usize,
}

#[derive(Clone, Debug)]
struct VisCfg {
    img: usize,
    patch: usize,
    d: usize,
    n_layers: usize,
    n_heads: usize,
}

#[derive(Clone, Debug)]
struct MmCfg {
    lm: LmCfg,
    vision: VisCfg,
    n_answers: usize,
    text_len: usize,
}

#[derive(Clone, Debug)]
enum RefProgram {
    Score(LmCfg),
    Step(LmCfg),
    LatentScore(LmCfg),
    LatentStep(LmCfg),
    MmScore(MmCfg),
}

fn cfg_usize(v: &Value, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(|x| x.as_usize())
        .ok_or_else(|| anyhow!("manifest config missing field {key:?}"))
}

fn lm_cfg(v: &Value) -> Result<LmCfg> {
    let cfg = LmCfg {
        vocab: cfg_usize(v, "vocab")?,
        d: cfg_usize(v, "d")?,
        n_layers: cfg_usize(v, "n_layers")?,
        n_heads: cfg_usize(v, "n_heads")?,
    };
    if cfg.n_heads == 0 || cfg.d % cfg.n_heads != 0 {
        bail!("config d={} is not divisible into n_heads={} \
               (the python reference rejects this shape too)",
              cfg.d, cfg.n_heads);
    }
    Ok(cfg)
}

fn model_cfg(manifest: &Value, model: &str) -> Result<LmCfg> {
    let v = manifest
        .path(&["models", model, "config"])
        .ok_or_else(|| anyhow!("manifest has no config for model {model:?}"))?;
    lm_cfg(v)
}

/// Resolve a latent program tag to its base model config via the
/// manifest's `latent_demo` record.
fn latent_cfg(manifest: &Value, tag: &str) -> Result<LmCfg> {
    let demo = manifest
        .get("latent_demo")
        .ok_or_else(|| anyhow!("manifest has no latent_demo record"))?;
    let known = demo.get("tag").and_then(|v| v.as_str()).unwrap_or("");
    if known != tag {
        bail!("latent tag {tag:?} not in manifest (latent_demo is {known:?})");
    }
    let model = demo
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("latent_demo missing model"))?;
    model_cfg(manifest, model)
}

fn mm_cfg(manifest: &Value) -> Result<MmCfg> {
    let mm = manifest
        .get("mm")
        .ok_or_else(|| anyhow!("manifest has no mm record"))?;
    let cfg = mm
        .get("config")
        .ok_or_else(|| anyhow!("mm record missing config"))?;
    let lmv = cfg.get("lm").ok_or_else(|| anyhow!("mm config missing lm"))?;
    let vv = cfg
        .get("vision")
        .ok_or_else(|| anyhow!("mm config missing vision"))?;
    let vision = VisCfg {
        img: cfg_usize(vv, "img")?,
        patch: cfg_usize(vv, "patch")?,
        d: cfg_usize(vv, "d")?,
        n_layers: cfg_usize(vv, "n_layers")?,
        n_heads: cfg_usize(vv, "n_heads")?,
    };
    if vision.n_heads == 0 || vision.d % vision.n_heads != 0 {
        bail!("vision config d={} is not divisible into n_heads={}",
              vision.d, vision.n_heads);
    }
    if vision.patch == 0 || vision.img % vision.patch != 0 {
        bail!("vision config img={} does not tile into patch={}",
              vision.img, vision.patch);
    }
    Ok(MmCfg {
        lm: lm_cfg(lmv)?,
        vision,
        n_answers: cfg_usize(cfg, "n_answers")?,
        text_len: cfg_usize(mm, "text_len")?,
    })
}

fn parse_program(name: &str, manifest: &Value) -> Result<RefProgram> {
    if let Some(tag) = name.strip_prefix("latent_score_") {
        return Ok(RefProgram::LatentScore(latent_cfg(manifest, tag)?));
    }
    if let Some(tag) = name.strip_prefix("latent_step_") {
        return Ok(RefProgram::LatentStep(latent_cfg(manifest, tag)?));
    }
    if let Some(model) = name.strip_prefix("score_") {
        return Ok(RefProgram::Score(model_cfg(manifest, model)?));
    }
    if let Some(model) = name.strip_prefix("step_") {
        return Ok(RefProgram::Step(model_cfg(manifest, model)?));
    }
    if name.strip_prefix("mm_score_").is_some() {
        return Ok(RefProgram::MmScore(mm_cfg(manifest)?));
    }
    bail!("no reference interpreter for program family of {name:?}")
}

// ---------------------------------------------------------------------------
// Shared numeric kernels (mirrors python/compile/kernels/ref.py)
// ---------------------------------------------------------------------------

const LN_EPS: f64 = 1e-5;

/// Row-wise layer norm over the feature axis.
fn layer_norm(x: &Matrix, g: &[f64], b: &[f64]) -> Matrix {
    let (t, d) = (x.rows(), x.cols());
    let mut out = Matrix::zeros(t, d);
    for i in 0..t {
        let row = x.row(i);
        let mu = row.iter().sum::<f64>() / d as f64;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>()
            / d as f64;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let orow = out.row_mut(i);
        for j in 0..d {
            orow[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
    out
}

/// y = x Wᵀ (+ b): the linear-layer application in the paper's W[out, in]
/// convention. THE layout dispatch point: every weight arrives as a
/// [`PackedMat`] and executes with its layout's kernel — the `DenseF64`
/// arm is exactly the old `x.matmul_bt(w)`, bit-identical by
/// construction (pinned by tests/layouts.rs).
fn linear(x: &Matrix, w: &PackedMat, b: Option<&[f64]>) -> Matrix {
    let mut y = w.apply(x);
    if let Some(b) = b {
        add_row_bias(&mut y, b);
    }
    y
}

fn add_row_bias(m: &mut Matrix, b: &[f64]) {
    for i in 0..m.rows() {
        for (v, bj) in m.row_mut(i).iter_mut().zip(b) {
            *v += bj;
        }
    }
}

fn relu_inplace(m: &mut Matrix) {
    for v in m.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place masked softmax over each row of a [m, n] score matrix.
/// `causal_from = Some(p)`: query row i sits at absolute position `p + i`
/// and sees key columns `..= p + i` (the full-window causal mask is the
/// `p = 0` case; a cached decode step is the one-row, `p = n - 1` case).
/// `None` is unmasked (the ViT tower).
fn softmax_rows(s: &mut Matrix, causal_from: Option<usize>) {
    for i in 0..s.rows() {
        let row = s.row_mut(i);
        if let Some(p) = causal_from {
            for v in row.iter_mut().skip(p + i + 1) {
                *v = f64::NEG_INFINITY;
            }
        }
        let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut total = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            total += *v;
        }
        let inv = 1.0 / total.max(1e-300);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Multi-head attention of `q` rows against the full `k`/`v` histories
/// (ref.mha). `q` may be fewer rows than `k`/`v`: the decode paths pass
/// only the *new* queries against all cached keys — `causal_from` places
/// them (see [`softmax_rows`]).
fn mha(q: &Matrix, k: &Matrix, v: &Matrix, h: usize,
       causal_from: Option<usize>) -> Matrix {
    let t = q.rows();
    let d = q.cols();
    // loud failure beats silently dropping the trailing columns a
    // truncating division would ignore (configs are validated upstream;
    // this guards weight tensors that disagree with the config)
    assert_eq!(d % h, 0, "attention width {d} not divisible by {h} heads");
    let dh = d / h;
    let scale = 1.0 / (dh as f64).sqrt();
    let mut ctx = Matrix::zeros(t, d);
    for head in 0..h {
        let qh = q.slice_cols(head * dh, (head + 1) * dh);
        let kh = k.slice_cols(head * dh, (head + 1) * dh);
        let vh = v.slice_cols(head * dh, (head + 1) * dh);
        let mut s = qh.matmul_bt(&kh).scale(scale);
        softmax_rows(&mut s, causal_from);
        let ch = s.matmul(&vh);
        for i in 0..t {
            ctx.row_mut(i)[head * dh..(head + 1) * dh]
                .copy_from_slice(ch.row(i));
        }
    }
    ctx
}

// --- augmented (bias-absorbing) products for the latent path ----------
//
// The MLA forward works on *raw* latent vectors plus an implicit
// trailing 1 — the augmentation column never materializes, so the decode
// cache stores exactly r_k / r_v floats per token (the paper's
// footprint). Accumulation is k-ascending with the ones term last,
// matching what an explicit append-ones + matmul/matmul_bt would do.

/// ([x | 1]) · a — `a` is [x.cols()+1, n], its last row multiplying the
/// implicit ones column.
fn matmul_ones_a(x: &Matrix, a: &Matrix) -> Matrix {
    let (m, r) = (x.rows(), x.cols());
    assert_eq!(a.rows(), r + 1, "augmented operand height");
    let n = a.cols();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let oi = out.row_mut(i);
        for c in 0..n {
            let mut acc = 0.0;
            for (k, &xv) in xi.iter().enumerate() {
                acc += xv * a[(k, c)];
            }
            oi[c] = acc + a[(r, c)];
        }
    }
    out
}

/// ([x | 1]) · bᵀ — `b` is [n, x.cols()+1], its last column multiplying
/// the implicit ones column.
fn matmul_ones_bt(x: &Matrix, b: &Matrix) -> Matrix {
    let (m, r) = (x.rows(), x.cols());
    assert_eq!(b.cols(), r + 1, "augmented operand width");
    let n = b.rows();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let oi = out.row_mut(i);
        for (j, ov) in oi.iter_mut().enumerate() {
            let bj = b.row(j);
            let mut acc = 0.0;
            for k in 0..r {
                acc += xi[k] * bj[k];
            }
            *ov = acc + bj[r];
        }
    }
    out
}

/// x · ([b | 1])ᵀ — each *row of b* carries an implicit trailing 1;
/// `x` is [m, b.cols()+1], its last column multiplying those ones. The
/// latent score kernel: augmented queries against raw cached latents.
fn matmul_bt_ones(x: &Matrix, b: &Matrix) -> Matrix {
    let (m, w) = (x.rows(), x.cols());
    let r = b.cols();
    assert_eq!(w, r + 1, "augmented operand width");
    let n = b.rows();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let oi = out.row_mut(i);
        for (j, ov) in oi.iter_mut().enumerate() {
            let bj = b.row(j);
            let mut acc = 0.0;
            for k in 0..r {
                acc += xi[k] * bj[k];
            }
            *ov = acc + xi[r];
        }
    }
    out
}

/// Blocked [`matmul_bt_ones`] for the packed execution layouts: four
/// cache rows per iteration, four independent accumulation chains. The
/// latent ranks are tiny (the inner dot is ~r_k long) while the cache
/// grows with the sequence, so the win comes from pipelining across
/// *rows*, not within a dot. Packed layouts have no bit-identity pin —
/// the exact-order kernel above stays the `DenseF64` path.
fn matmul_bt_ones_fast(x: &Matrix, b: &Matrix) -> Matrix {
    let (m, w) = (x.rows(), x.cols());
    let r = b.cols();
    assert_eq!(w, r + 1, "augmented operand width");
    let n = b.rows();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let ones = xi[r];
        let oi = out.row_mut(i);
        let mut j = 0usize;
        while j + 4 <= n {
            let (b0, b1) = (b.row(j), b.row(j + 1));
            let (b2, b3) = (b.row(j + 2), b.row(j + 3));
            let (mut a0, mut a1) = (ones, ones);
            let (mut a2, mut a3) = (ones, ones);
            for k in 0..r {
                let xk = xi[k];
                a0 += xk * b0[k];
                a1 += xk * b1[k];
                a2 += xk * b2[k];
                a3 += xk * b3[k];
            }
            oi[j] = a0;
            oi[j + 1] = a1;
            oi[j + 2] = a2;
            oi[j + 3] = a3;
            j += 4;
        }
        while j < n {
            let bj = b.row(j);
            let mut acc = ones;
            for k in 0..r {
                acc += xi[k] * bj[k];
            }
            oi[j] = acc;
            j += 1;
        }
    }
    out
}

/// Mean next-token NLL of one sequence (python model.nll).
fn mean_nll(logits: &Matrix, tokens: &[i32]) -> f64 {
    let t = logits.rows().min(tokens.len());
    if t < 2 {
        return 0.0;
    }
    let vocab = logits.cols();
    let mut total = 0.0;
    for i in 0..t - 1 {
        let row = logits.row(i);
        let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = row.iter().map(|v| (v - max).exp()).sum::<f64>().ln() + max;
        let tgt = clamp_token(tokens[i + 1], vocab);
        total += lse - row[tgt];
    }
    total / (t - 1) as f64
}

fn clamp_token(tok: i32, vocab: usize) -> usize {
    (tok.max(0) as usize).min(vocab.saturating_sub(1))
}

/// The embedding table is the one tensor whose shape the manifest config
/// fully determines — validate it so a weights/config mismatch fails with
/// a message instead of garbage numerics.
fn check_emb(tok_emb: &Matrix, cfg: &LmCfg) -> Result<()> {
    if tok_emb.rows() != cfg.vocab || tok_emb.cols() != cfg.d {
        bail!("tok_emb is {}x{} but the manifest config says vocab={} d={}",
              tok_emb.rows(), tok_emb.cols(), cfg.vocab, cfg.d);
    }
    Ok(())
}

/// Attention weight rows must split evenly into heads; catching it at
/// load time keeps [`mha`]'s internal assert unreachable through any
/// loader (a panic there would kill the serve worker thread, whereas an
/// Err is counted and reported per batch).
fn check_heads(layers: &[DenseLayer], h: usize, what: &str) -> Result<()> {
    for (i, l) in layers.iter().enumerate() {
        let d = l.wq.rows();
        if l.wk.rows() != d || l.wv.rows() != d || h == 0 || d % h != 0 {
            bail!("{what} layer {i}: attn widths q={} k={} v={} do not \
                   split into {h} heads", l.wq.rows(), l.wk.rows(),
                  l.wv.rows());
        }
    }
    Ok(())
}

/// Token + learned-positional embedding rows at absolute positions
/// `pos0..pos0 + tokens.len()` (python: `tok_emb[tokens] + pos_emb[:t]`
/// is the `pos0 = 0` case) — shared by the dense and latent forwards and
/// the incremental decode sessions.
fn embed_tokens(tok_emb: &Matrix, pos_emb: &Matrix, tokens: &[i32],
                pos0: usize) -> Matrix {
    let t = tokens.len();
    let d = tok_emb.cols();
    let vocab = tok_emb.rows();
    let mut x = Matrix::zeros(t, d);
    for (i, &tok) in tokens.iter().enumerate() {
        let e = tok_emb.row(clamp_token(tok, vocab));
        let p = pos_emb.row((pos0 + i).min(pos_emb.rows() - 1));
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = e[j] + p[j];
        }
    }
    x
}

/// Final layer norm + tied LM head (python: `_ln(x, lnf) @ tok_emb.T`).
/// `head` is the embedding table in its execution layout — the vocab
/// projection is the single biggest matmul of a decode step, so it
/// dispatches like every other linear.
fn tied_head(x: &Matrix, lnf_g: &[f64], lnf_b: &[f64], head: &PackedMat)
             -> Matrix {
    head.apply(&layer_norm(x, lnf_g, lnf_b))
}

/// Sequences longer than the learned positional table would silently
/// reuse its last row (quietly wrong logits) where the compiled PJRT
/// program rejects the shape — reject them here too.
fn check_seq_len(t: usize, pos_rows: usize) -> Result<()> {
    if t > pos_rows {
        bail!("sequence length {t} exceeds the model's positional table \
               ({pos_rows} rows / max_len)");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Weight views
// ---------------------------------------------------------------------------

fn mat(w: &Weights, name: &str) -> Result<Matrix> {
    w.matrix(name)
}

/// Execution-layout view: what every `matmul_bt`-shaped weight loads
/// through (dense f64 on LTW1 artifacts, panels/int8 on LTW2 ones).
fn pmat(w: &Weights, name: &str) -> Result<PackedMat> {
    w.packed(name)
}

fn vecf(w: &Weights, name: &str) -> Result<Vec<f64>> {
    w.bias(name)
}

/// Split a [h, a, b] tensor into h dense [a, b] matrices.
fn head_matrices(t: &Tensor, name: &str) -> Result<Vec<Matrix>> {
    let shape = t.shape().to_vec();
    if shape.len() != 3 {
        bail!("{name}: expected 3-D head tensor, got {shape:?}");
    }
    let (h, a, b) = (shape[0], shape[1], shape[2]);
    let data = t.as_f32().with_context(|| name.to_string())?;
    Ok((0..h)
        .map(|i| {
            Matrix::from_fn(a, b, |r, c| {
                data[i * a * b + r * b + c] as f64
            })
        })
        .collect())
}

/// One pre-LN transformer block's dense weights (shared by the LM, the
/// ViT tower, and the multimodal LM tower via key prefixes).
struct DenseLayer {
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    wq: PackedMat,
    bq: Vec<f64>,
    wk: PackedMat,
    bk: Vec<f64>,
    wv: PackedMat,
    bv: Vec<f64>,
    wo: PackedMat,
    bo: Vec<f64>,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
    wu: PackedMat,
    bu: Vec<f64>,
    wd: PackedMat,
    bd: Vec<f64>,
}

impl DenseLayer {
    fn load(w: &Weights, prefix: &str) -> Result<DenseLayer> {
        Ok(DenseLayer {
            ln1_g: vecf(w, &format!("{prefix}ln1.g"))?,
            ln1_b: vecf(w, &format!("{prefix}ln1.b"))?,
            wq: pmat(w, &format!("{prefix}attn.wq"))?,
            bq: vecf(w, &format!("{prefix}attn.bq"))?,
            wk: pmat(w, &format!("{prefix}attn.wk"))?,
            bk: vecf(w, &format!("{prefix}attn.bk"))?,
            wv: pmat(w, &format!("{prefix}attn.wv"))?,
            bv: vecf(w, &format!("{prefix}attn.bv"))?,
            wo: pmat(w, &format!("{prefix}attn.wo"))?,
            bo: vecf(w, &format!("{prefix}attn.bo"))?,
            ln2_g: vecf(w, &format!("{prefix}ln2.g"))?,
            ln2_b: vecf(w, &format!("{prefix}ln2.b"))?,
            wu: pmat(w, &format!("{prefix}mlp.wu"))?,
            bu: vecf(w, &format!("{prefix}mlp.bu"))?,
            wd: pmat(w, &format!("{prefix}mlp.wd"))?,
            bd: vecf(w, &format!("{prefix}mlp.bd"))?,
        })
    }

    /// One pre-LN block over `x` rows, reading and *extending* the
    /// `kc`/`vc` caches: the rows' K/V projections are appended, then
    /// their queries attend over the whole cache. With a fresh cache this
    /// IS the full-window forward; with a populated one it is the decode
    /// prefill/step — one body, so the paths cannot drift. Causal rows
    /// sit at absolute positions `kc.rows()..`; non-causal (the ViT
    /// tower) attends everything.
    fn forward_cached(&self, x: Matrix, h: usize, causal: bool,
                      kc: &mut Matrix, vc: &mut Matrix) -> Matrix {
        let layout = self.wq.layout().name();
        let t0 = profile::phase_start();
        let (q, knew, vnew) = self.attn_weight_phase(&x);
        profile::phase_end(t0, "dense", "attn_weight", layout);
        let t0 = profile::phase_start();
        let ctx = self.attn_cache_phase(&q, &knew, &vnew, h, causal, kc, vc);
        profile::phase_end(t0, "dense", "attn_cache", layout);
        let t0 = profile::phase_start();
        let out = self.finish_phase(x, &ctx);
        profile::phase_end(t0, "dense", "finish", layout);
        out
    }

    /// Weight side of the block's attention: LN1 plus the q/k/v
    /// projections. Every kernel here computes each output row
    /// independently in the same k-order regardless of how many rows are
    /// stacked, so the fused multi-session step runs this once over N
    /// sequences' rows and gets bit-identical numbers to N separate
    /// calls. No cache state is read or written.
    fn attn_weight_phase(&self, x: &Matrix) -> (Matrix, Matrix, Matrix) {
        let xa = layer_norm(x, &self.ln1_g, &self.ln1_b);
        let q = linear(&xa, &self.wq, Some(&self.bq));
        let k = linear(&xa, &self.wk, Some(&self.bk));
        let v = linear(&xa, &self.wv, Some(&self.bv));
        (q, k, v)
    }

    /// Cache side: append this sequence's new K/V rows and attend its
    /// queries over its own (now-extended) cache — the only per-sequence
    /// arithmetic in the block, and the only part the fused step fans
    /// out. Causal rows sit at absolute positions `kc.rows()..`.
    fn attn_cache_phase(&self, q: &Matrix, knew: &Matrix, vnew: &Matrix,
                        h: usize, causal: bool,
                        kc: &mut Matrix, vc: &mut Matrix) -> Matrix {
        let pos0 = kc.rows();
        kc.push_rows(knew);
        vc.push_rows(vnew);
        mha(q, kc, vc, h, causal.then_some(pos0))
    }

    /// Weight side after attention: output projection residual, LN2 and
    /// the MLP — row-independent like [`DenseLayer::attn_weight_phase`].
    fn finish_phase(&self, x: Matrix, ctx: &Matrix) -> Matrix {
        let mut x = x.add(&linear(ctx, &self.wo, Some(&self.bo)));
        let xm = layer_norm(&x, &self.ln2_g, &self.ln2_b);
        let mut z = linear(&xm, &self.wu, Some(&self.bu));
        relu_inplace(&mut z);
        x.add_inplace(&linear(&z, &self.wd, Some(&self.bd)));
        x
    }

    /// One pre-LN block over [t, d] tokens (python model.forward body /
    /// multimodal._block): [`DenseLayer::forward_cached`] against a
    /// throwaway cache.
    fn forward(&self, x: Matrix, h: usize, causal: bool) -> Matrix {
        let mut kc = Matrix::zeros(0, self.wk.rows());
        let mut vc = Matrix::zeros(0, self.wv.rows());
        self.forward_cached(x, h, causal, &mut kc, &mut vc)
    }
}

struct DenseModel {
    /// Dense embedding view — row-gathered by [`embed_tokens`] (the
    /// dequantized values on an int8 artifact, so embeddings and head
    /// read the same grid).
    tok_emb: Matrix,
    /// The same table in its execution layout for the tied LM head.
    head: PackedMat,
    pos_emb: Matrix,
    layers: Vec<DenseLayer>,
    lnf_g: Vec<f64>,
    lnf_b: Vec<f64>,
    n_heads: usize,
}

impl DenseModel {
    fn load(w: &Weights, cfg: &LmCfg) -> Result<DenseModel> {
        let tok_emb = mat(w, "tok_emb")?;
        check_emb(&tok_emb, cfg)?;
        let layers = (0..cfg.n_layers)
            .map(|i| DenseLayer::load(w, &format!("layers.{i}.")))
            .collect::<Result<Vec<_>>>()?;
        check_heads(&layers, cfg.n_heads, "dense")?;
        Ok(DenseModel {
            tok_emb,
            head: pmat(w, "tok_emb")?,
            pos_emb: mat(w, "pos_emb")?,
            layers,
            lnf_g: vecf(w, "lnf.g")?,
            lnf_b: vecf(w, "lnf.b")?,
            n_heads: cfg.n_heads,
        })
    }

    /// tokens [t] → logits [t, vocab] (tied LM head).
    fn forward(&self, tokens: &[i32]) -> Matrix {
        let mut x = embed_tokens(&self.tok_emb, &self.pos_emb, tokens, 0);
        for layer in &self.layers {
            x = layer.forward(x, self.n_heads, true);
        }
        tied_head(&x, &self.lnf_g, &self.lnf_b, &self.head)
    }
}

// ---------------------------------------------------------------------------
// Latent (MLA) model — python model.latent_forward
// ---------------------------------------------------------------------------

struct LatentLayer {
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    aq: PackedMat,
    ak: PackedMat,
    av: PackedMat,
    /// per-head augmented score core [rq+1, rk+1] (bias-absorbed).
    /// Stays f64: tiny (rank-sized), rebuilt from the head tensors at
    /// load, and consumed by the augmented kernels, not `linear`.
    h_aug: Vec<Matrix>,
    /// per-head augmented value decompressor [dh, rv+1]
    bv_aug: Vec<Matrix>,
    ao_heads: PackedMat,
    bo_mat: PackedMat,
    bo: Vec<f64>,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
    au: PackedMat,
    bu_mat: PackedMat,
    bu: Vec<f64>,
    ad: PackedMat,
    bd_mat: PackedMat,
    bd: Vec<f64>,
    /// Packed execution layout in play → use the blocked (non-pinned)
    /// variants of the cache-side kernels too; `DenseF64` keeps the
    /// exact-order kernels so pre-layout results stay bit-identical.
    fast: bool,
}

impl LatentLayer {
    fn load(w: &Weights, prefix: &str, h: usize, dh: usize)
            -> Result<LatentLayer> {
        let bq_heads = head_matrices(
            w.tensor(&format!("{prefix}attn.bq_heads"))?,
            &format!("{prefix}attn.bq_heads"))?;
        let bk_heads = head_matrices(
            w.tensor(&format!("{prefix}attn.bk_heads"))?,
            &format!("{prefix}attn.bk_heads"))?;
        let bv_heads = head_matrices(
            w.tensor(&format!("{prefix}attn.bv_heads"))?,
            &format!("{prefix}attn.bv_heads"))?;
        let bq_b = vecf(w, &format!("{prefix}attn.bq"))?;
        let bk_b = vecf(w, &format!("{prefix}attn.bk"))?;
        let bv_b = vecf(w, &format!("{prefix}attn.bv"))?;
        if bq_heads.len() != h || bk_heads.len() != h || bv_heads.len() != h {
            bail!("{prefix}: head tensors disagree with n_heads={h}");
        }
        // the per-head slicing below assumes full-width [d] biases
        for (name, b) in [("bq", &bq_b), ("bk", &bk_b), ("bv", &bv_b)] {
            if b.len() != h * dh {
                bail!("{prefix}attn.{name} has {} entries, expected \
                       n_heads*d_h = {}", b.len(), h * dh);
            }
        }

        // QKV biases survive the latent path through bilinear augmentation
        // (python latent_forward): per head
        //   H̃ᵢ = [[BqᵢᵀBkᵢ, Bqᵢᵀbkᵢ], [bqᵢᵀBkᵢ, bqᵢᵀbkᵢ]]
        //   B̃vᵢ = [Bvᵢ  bvᵢ]
        let mut h_aug = Vec::with_capacity(h);
        let mut bv_aug = Vec::with_capacity(h);
        for i in 0..h {
            let bqh = &bq_heads[i]; // [dh, rq]
            let bkh = &bk_heads[i]; // [dh, rk]
            let bvh = &bv_heads[i]; // [dh, rv]
            if bqh.rows() != dh || bkh.rows() != dh || bvh.rows() != dh {
                bail!("{prefix} head {i}: decompressor rows q={} k={} v={} \
                       disagree with d_h={dh}", bqh.rows(), bkh.rows(),
                      bvh.rows());
            }
            let (rq, rk) = (bqh.cols(), bkh.cols());
            let bq_i = &bq_b[i * dh..(i + 1) * dh];
            let bk_i = &bk_b[i * dh..(i + 1) * dh];
            let bv_i = &bv_b[i * dh..(i + 1) * dh];
            let core = bqh.matmul_at(bkh); // [rq, rk]
            let mut aug = Matrix::zeros(rq + 1, rk + 1);
            for q in 0..rq {
                for k in 0..rk {
                    aug[(q, k)] = core[(q, k)];
                }
                aug[(q, rk)] = (0..dh)
                    .map(|dd| bqh[(dd, q)] * bk_i[dd])
                    .sum();
            }
            for k in 0..rk {
                aug[(rq, k)] = (0..dh)
                    .map(|dd| bq_i[dd] * bkh[(dd, k)])
                    .sum();
            }
            aug[(rq, rk)] = (0..dh).map(|dd| bq_i[dd] * bk_i[dd]).sum();
            h_aug.push(aug);

            let rv = bvh.cols();
            let mut va = Matrix::zeros(dh, rv + 1);
            for dd in 0..dh {
                for r in 0..rv {
                    va[(dd, r)] = bvh[(dd, r)];
                }
                va[(dd, rv)] = bv_i[dd];
            }
            bv_aug.push(va);
        }

        // the compression planes must agree with the per-head
        // decompressors on the latent ranks, or forward()'s matmuls
        // panic instead of erroring (same contract as check_heads)
        let aq = pmat(w, &format!("{prefix}attn.aq"))?;
        let ak = pmat(w, &format!("{prefix}attn.ak"))?;
        let av = pmat(w, &format!("{prefix}attn.av"))?;
        for (name, plane, heads) in [("q", &aq, &bq_heads),
                                     ("k", &ak, &bk_heads),
                                     ("v", &av, &bv_heads)] {
            if heads.iter().any(|m| m.cols() != plane.rows()) {
                bail!("{prefix}attn.a{name} has rank {} but a \
                       b{name}_heads slice disagrees", plane.rows());
            }
        }
        let ao_heads = pmat(w, &format!("{prefix}attn.ao_heads"))?;
        if ao_heads.cols() != h * dh {
            bail!("{prefix}attn.ao_heads spans {} features, expected \
                   n_heads*d_h = {}", ao_heads.cols(), h * dh);
        }
        let fast = [&aq, &ak, &av, &ao_heads]
            .iter()
            .any(|p| p.layout() != Layout::DenseF64);
        Ok(LatentLayer {
            ln1_g: vecf(w, &format!("{prefix}ln1.g"))?,
            ln1_b: vecf(w, &format!("{prefix}ln1.b"))?,
            aq,
            ak,
            av,
            h_aug,
            bv_aug,
            ao_heads,
            bo_mat: pmat(w, &format!("{prefix}attn.bo_mat"))?,
            bo: vecf(w, &format!("{prefix}attn.bo"))?,
            ln2_g: vecf(w, &format!("{prefix}ln2.g"))?,
            ln2_b: vecf(w, &format!("{prefix}ln2.b"))?,
            au: pmat(w, &format!("{prefix}mlp.au"))?,
            bu_mat: pmat(w, &format!("{prefix}mlp.bu_mat"))?,
            bu: vecf(w, &format!("{prefix}mlp.bu"))?,
            ad: pmat(w, &format!("{prefix}mlp.ad"))?,
            bd_mat: pmat(w, &format!("{prefix}mlp.bd_mat"))?,
            bd: vecf(w, &format!("{prefix}mlp.bd"))?,
            fast,
        })
    }

    /// The MLA block over `x` rows, reading and *extending* the latent
    /// caches (`ck` [t, r_k], `cv` [t, r_v] — raw latents; the ones
    /// augmentation stays implicit, see the `matmul_*ones*` kernels).
    /// Fresh caches give the full-window forward, populated ones the
    /// decode prefill/step — one body, so the paths cannot drift.
    fn forward_cached(&self, x: Matrix, h: usize, dh: usize,
                      ck: &mut Matrix, cv: &mut Matrix) -> Matrix {
        let layout = self.aq.layout().name();
        let t0 = profile::phase_start();
        let (q, cknew, cvnew) = self.attn_weight_phase(&x);
        profile::phase_end(t0, "latent", "attn_weight", layout);
        let t0 = profile::phase_start();
        let ctx = self.attn_cache_phase(&q, &cknew, &cvnew, h, dh, ck, cv);
        profile::phase_end(t0, "latent", "attn_cache", layout);
        let t0 = profile::phase_start();
        let out = self.finish_phase(x, &ctx);
        profile::phase_end(t0, "latent", "finish", layout);
        out
    }

    /// Weight side: LN1 plus the latent compression planes (q latents
    /// and the new cache rows). Row-independent — the fused step stacks
    /// N sequences' rows through these GEMMs once, bit-identically.
    fn attn_weight_phase(&self, x: &Matrix) -> (Matrix, Matrix, Matrix) {
        let xa = layer_norm(x, &self.ln1_g, &self.ln1_b);
        let q = linear(&xa, &self.aq, None); // [t, rq]
        let cknew = linear(&xa, &self.ak, None);
        let cvnew = linear(&xa, &self.av, None);
        (q, cknew, cvnew)
    }

    /// Cache side: append this sequence's new latents and run the
    /// per-head latent attention against its own cache — the only
    /// per-sequence arithmetic (the tiny rank-sized `h_aug`/`bv_aug`
    /// products ride along; they are row-independent too, so keeping
    /// them here changes nothing numerically).
    fn attn_cache_phase(&self, q: &Matrix, cknew: &Matrix, cvnew: &Matrix,
                        h: usize, dh: usize,
                        ck: &mut Matrix, cv: &mut Matrix) -> Matrix {
        let t = q.rows();
        let pos0 = ck.rows();
        ck.push_rows(cknew);
        cv.push_rows(cvnew);

        // latent attention per head: scores never materialize full K
        // (ref.latent_attention); only the compressed latents are read
        let scale = 1.0 / (dh as f64).sqrt();
        let mut ctx = Matrix::zeros(t, h * dh);
        for head in 0..h {
            // ũ = [q|1]·H̃ per head, then scores against cached latents
            let u = matmul_ones_a(q, &self.h_aug[head]); // [t, rk+1]
            let s_raw = if self.fast {
                matmul_bt_ones_fast(&u, ck)
            } else {
                matmul_bt_ones(&u, ck)
            };
            let mut s = s_raw.scale(scale);
            softmax_rows(&mut s, Some(pos0));
            let ctx_lat = s.matmul(cv); // [t, rv]
            // softmax rows sum to one, so the augmented ones column
            // contributes exactly B̃v's bias column
            let ch = matmul_ones_bt(&ctx_lat, &self.bv_aug[head]); // [t, dh]
            for i in 0..t {
                ctx.row_mut(i)[head * dh..(head + 1) * dh]
                    .copy_from_slice(ch.row(i));
            }
        }
        ctx
    }

    /// Weight side after attention: low-rank output projection residual,
    /// LN2 and the low-rank MLP (ref.lowrank_matmul) — row-independent.
    fn finish_phase(&self, x: Matrix, ctx: &Matrix) -> Matrix {
        // low-rank output projection: (ctx Aoᵀ) Boᵀ + bo
        let mut x = x.add(&linear(
            &linear(ctx, &self.ao_heads, None),
            &self.bo_mat,
            Some(&self.bo),
        ));
        let xm = layer_norm(&x, &self.ln2_g, &self.ln2_b);
        let mut z = linear(&linear(&xm, &self.au, None), &self.bu_mat,
                           Some(&self.bu));
        relu_inplace(&mut z);
        let y = linear(&linear(&z, &self.ad, None), &self.bd_mat,
                       Some(&self.bd));
        x.add_inplace(&y);
        x
    }

    /// Full-window MLA block: [`LatentLayer::forward_cached`] against a
    /// throwaway cache.
    fn forward(&self, x: Matrix, h: usize, dh: usize) -> Matrix {
        let mut ck = Matrix::zeros(0, self.ak.rows());
        let mut cv = Matrix::zeros(0, self.av.rows());
        self.forward_cached(x, h, dh, &mut ck, &mut cv)
    }
}

struct LatentModel {
    tok_emb: Matrix,
    head: PackedMat,
    pos_emb: Matrix,
    layers: Vec<LatentLayer>,
    lnf_g: Vec<f64>,
    lnf_b: Vec<f64>,
    n_heads: usize,
    d_h: usize,
}

impl LatentModel {
    fn load(w: &Weights, cfg: &LmCfg) -> Result<LatentModel> {
        let dh = cfg.d / cfg.n_heads.max(1);
        let tok_emb = mat(w, "tok_emb")?;
        check_emb(&tok_emb, cfg)?;
        let layers = (0..cfg.n_layers)
            .map(|i| {
                LatentLayer::load(w, &format!("layers.{i}."), cfg.n_heads, dh)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LatentModel {
            tok_emb,
            head: pmat(w, "tok_emb")?,
            pos_emb: mat(w, "pos_emb")?,
            layers,
            lnf_g: vecf(w, "lnf.g")?,
            lnf_b: vecf(w, "lnf.b")?,
            n_heads: cfg.n_heads,
            d_h: dh,
        })
    }

    fn forward(&self, tokens: &[i32]) -> Matrix {
        let mut x = embed_tokens(&self.tok_emb, &self.pos_emb, tokens, 0);
        for layer in &self.layers {
            x = layer.forward(x, self.n_heads, self.d_h);
        }
        tied_head(&x, &self.lnf_g, &self.lnf_b, &self.head)
    }
}

// ---------------------------------------------------------------------------
// Multimodal model — python multimodal.forward
// ---------------------------------------------------------------------------

struct MmModel {
    patch_w: PackedMat,
    patch_b: Vec<f64>,
    vit_pos: Matrix,
    vit_layers: Vec<DenseLayer>,
    vit_lnf_g: Vec<f64>,
    vit_lnf_b: Vec<f64>,
    proj_w: PackedMat,
    proj_b: Vec<f64>,
    lm_tok_emb: Matrix,
    lm_pos_emb: Matrix,
    lm_layers: Vec<DenseLayer>,
    lm_lnf_g: Vec<f64>,
    lm_lnf_b: Vec<f64>,
    ans_w: Matrix,
    ans_b: Vec<f64>,
    cfg: MmCfg,
}

impl MmModel {
    fn load(w: &Weights, cfg: &MmCfg) -> Result<MmModel> {
        let vit_layers = (0..cfg.vision.n_layers)
            .map(|i| DenseLayer::load(w, &format!("vit.layers.{i}.")))
            .collect::<Result<Vec<_>>>()?;
        check_heads(&vit_layers, cfg.vision.n_heads, "vit")?;
        let lm_layers = (0..cfg.lm.n_layers)
            .map(|i| DenseLayer::load(w, &format!("lm.layers.{i}.")))
            .collect::<Result<Vec<_>>>()?;
        check_heads(&lm_layers, cfg.lm.n_heads, "mm-lm")?;
        let vit_pos = mat(w, "vit.pos")?;
        let grid = cfg.vision.img / cfg.vision.patch.max(1);
        let n_patches = grid * grid;
        if vit_pos.rows() < n_patches {
            bail!("vit.pos has {} rows but the vision config implies \
                   {n_patches} patches", vit_pos.rows());
        }
        let patch_w = pmat(w, "vit.patch.w")?;
        if patch_w.rows() != cfg.vision.d {
            bail!("vit.patch.w emits {} features but the vision config \
                   says d={}", patch_w.rows(), cfg.vision.d);
        }
        let proj_w = pmat(w, "proj.w")?;
        if proj_w.rows() != cfg.lm.d || proj_w.cols() != cfg.vision.d {
            bail!("proj.w is {}x{} but the configs say lm.d={} vision.d={}",
                  proj_w.rows(), proj_w.cols(), cfg.lm.d, cfg.vision.d);
        }
        let lm_tok_emb = mat(w, "lm.tok_emb")?;
        check_emb(&lm_tok_emb, &cfg.lm)?;
        let lm_pos_emb = mat(w, "lm.pos_emb")?;
        check_seq_len(n_patches + cfg.text_len, lm_pos_emb.rows())?;
        Ok(MmModel {
            patch_w,
            patch_b: vecf(w, "vit.patch.b")?,
            vit_pos,
            vit_layers,
            vit_lnf_g: vecf(w, "vit.lnf.g")?,
            vit_lnf_b: vecf(w, "vit.lnf.b")?,
            proj_w,
            proj_b: vecf(w, "proj.b")?,
            lm_tok_emb,
            lm_pos_emb,
            lm_layers,
            lm_lnf_g: vecf(w, "lm.lnf.g")?,
            lm_lnf_b: vecf(w, "lm.lnf.b")?,
            ans_w: mat(w, "ans.w")?,
            ans_b: vecf(w, "ans.b")?,
            cfg: cfg.clone(),
        })
    }

    /// image [img*img] row-major, tokens [text_len] → answer logits.
    fn forward(&self, image: &[f32], tokens: &[i32]) -> Vec<f64> {
        let v = &self.cfg.vision;
        let grid = v.img / v.patch;
        let n_patches = grid * grid;
        let patch_dim = v.patch * v.patch;
        // patchify: patch (pi, pj) flattened row-major (multimodal.forward)
        let mut patches = Matrix::zeros(n_patches, patch_dim);
        for pi in 0..grid {
            for pj in 0..grid {
                let row = patches.row_mut(pi * grid + pj);
                for a in 0..v.patch {
                    for b in 0..v.patch {
                        row[a * v.patch + b] =
                            image[(pi * v.patch + a) * v.img
                                  + pj * v.patch + b] as f64;
                    }
                }
            }
        }
        let mut x = linear(&patches, &self.patch_w, Some(&self.patch_b));
        for i in 0..x.rows() {
            let pos = self.vit_pos.row(i);
            for (a, p) in x.row_mut(i).iter_mut().zip(pos) {
                *a += p;
            }
        }
        for layer in &self.vit_layers {
            x = layer.forward(x, v.n_heads, false);
        }
        let x = layer_norm(&x, &self.vit_lnf_g, &self.vit_lnf_b);
        let vis = linear(&x, &self.proj_w, Some(&self.proj_b));

        let d_lm = self.lm_tok_emb.cols();
        let vocab = self.lm_tok_emb.rows();
        let seq_t = n_patches + tokens.len();
        let mut seq = Matrix::zeros(seq_t, d_lm);
        for i in 0..n_patches {
            seq.row_mut(i).copy_from_slice(vis.row(i));
        }
        for (i, &tok) in tokens.iter().enumerate() {
            seq.row_mut(n_patches + i)
                .copy_from_slice(self.lm_tok_emb.row(clamp_token(tok, vocab)));
        }
        for i in 0..seq_t {
            let pos = self.lm_pos_emb.row(i.min(self.lm_pos_emb.rows() - 1));
            for (a, p) in seq.row_mut(i).iter_mut().zip(pos) {
                *a += p;
            }
        }
        for layer in &self.lm_layers {
            seq = layer.forward(seq, self.cfg.lm.n_heads, true);
        }
        let seq = layer_norm(&seq, &self.lm_lnf_g, &self.lm_lnf_b);
        let last: Vec<f64> = seq.row(seq_t - 1).to_vec();
        let mut out = self.ans_w.matvec(&last);
        for (o, b) in out.iter_mut().zip(&self.ans_b) {
            *o += b;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Executable dispatch
// ---------------------------------------------------------------------------

/// Models converted from a specific weight set (f32 → f64, per-head bias
/// augmentation precomputed).
enum LoadedModel {
    Dense(DenseModel),
    Latent(LatentModel),
    Mm(MmModel),
}

/// Few-entry memo map: the serve path alternates two weight sets (dense +
/// latent variant) on ONE program name, so a single-slot cache would
/// thrash; report sweeps create many transient weight sets, so an
/// unbounded map would hoard memory. Cap small and reset when exceeded.
/// Values are `Arc` so live decode sessions keep their model alive across
/// a cache reset.
const MODEL_CACHE_CAP: usize = 4;
type ModelCache = std::collections::HashMap<u64, std::sync::Arc<LoadedModel>>;

struct RefExecutable {
    kind: RefProgram,
    /// Memoized models keyed by [`Weights::cache_id`]: weights are program
    /// *parameters* (they arrive at execute time, not compile time), but
    /// the decode loop and the serving path call execute repeatedly with
    /// the same set(s) — rebuilding per call would cost O(tokens × params).
    cache: std::sync::Mutex<ModelCache>,
}

impl RefExecutable {
    /// The loaded model for this weight set, (re)loading into the memo
    /// map on a miss. The lock is held only for map lookups/inserts —
    /// never across a model build — and is taken poison-tolerantly
    /// ([`crate::util::lock_unpoisoned`]): a worker thread panicking
    /// mid-execution must not turn every sibling's cache access into a
    /// `PoisonError` unwrap cascade. Two threads racing a miss may both
    /// build; the second insert wins and the loser's Arc just drops.
    fn loaded(&self, weights: &Weights)
              -> Result<std::sync::Arc<LoadedModel>> {
        let id = weights.cache_id();
        if let Some(m) = crate::util::lock_unpoisoned(&self.cache).get(&id) {
            return Ok(m.clone());
        }
        let model = match &self.kind {
            RefProgram::Score(cfg) | RefProgram::Step(cfg) => {
                LoadedModel::Dense(DenseModel::load(weights, cfg)?)
            }
            RefProgram::LatentScore(cfg)
            | RefProgram::LatentStep(cfg) => {
                LoadedModel::Latent(LatentModel::load(weights, cfg)?)
            }
            RefProgram::MmScore(cfg) => {
                LoadedModel::Mm(MmModel::load(weights, cfg)?)
            }
        };
        let model = std::sync::Arc::new(model);
        let mut g = crate::util::lock_unpoisoned(&self.cache);
        if g.len() >= MODEL_CACHE_CAP {
            g.clear();
        }
        g.insert(id, model.clone());
        Ok(model)
    }
}

// ---------------------------------------------------------------------------
// Incremental decode sessions
// ---------------------------------------------------------------------------

/// Stateful single-sequence decode over a loaded dense or latent model:
/// the cache tensors live in [`DecodeState`]; every forward goes through
/// the same `forward_cached` layer bodies as the full-window programs, so
/// prefill+step is token-for-token identical to recompute (pinned by
/// tests/decode.rs).
struct RefDecodeSession {
    model: std::sync::Arc<LoadedModel>,
    state: DecodeState,
    kind: CacheKind,
    /// positional-table rows — the session's hard token capacity
    max_tokens: usize,
}

impl RefDecodeSession {
    fn open(model: std::sync::Arc<LoadedModel>)
            -> Result<RefDecodeSession> {
        let (layers, kind, max_tokens) = match &*model {
            LoadedModel::Dense(m) => {
                let layers: Vec<LayerCache> = m.layers.iter()
                    .map(|l| LayerCache::dense(l.wk.rows()))
                    .collect();
                let d = m.layers.first().map(|l| l.wk.rows()).unwrap_or(0);
                (layers, CacheKind::Dense { d }, m.pos_emb.rows())
            }
            LoadedModel::Latent(m) => {
                let layers: Vec<LayerCache> = m.layers.iter()
                    .map(|l| LayerCache::latent(l.ak.rows(), l.av.rows()))
                    .collect();
                let (rk, rv) = m.layers.first()
                    .map(|l| (l.ak.rows(), l.av.rows()))
                    .unwrap_or((0, 0));
                (layers, CacheKind::Latent { rk, rv }, m.pos_emb.rows())
            }
            LoadedModel::Mm(_) => {
                bail!("multimodal programs have no decode sessions")
            }
        };
        Ok(RefDecodeSession {
            model,
            state: DecodeState::new(layers),
            kind,
            max_tokens,
        })
    }

    /// Run `tokens` (the prompt at prefill, one token per step, a chunk
    /// in `step_many`) through every layer at absolute positions
    /// `cached..`, extending the layer caches, and return the logits —
    /// every fed row's when `all_rows`, else the final row only. The
    /// rows are arithmetically independent given the cache contents
    /// before them (causal masking zeroes the future *exactly*), so a
    /// multi-row chunk is bit-identical to feeding its tokens one call
    /// at a time.
    fn forward_rows(&mut self, tokens: &[i32], all_rows: bool)
                    -> Result<Matrix> {
        let pos0 = self.state.cached_tokens();
        let last_only = |x: Matrix| {
            if all_rows {
                x
            } else {
                x.slice_rows(x.rows() - 1, x.rows())
            }
        };
        let logits = match &*self.model {
            LoadedModel::Dense(m) => {
                check_seq_len(pos0 + tokens.len(), m.pos_emb.rows())?;
                let mut x = embed_tokens(&m.tok_emb, &m.pos_emb, tokens,
                                         pos0);
                for (layer, cache) in
                    m.layers.iter().zip(self.state.layers.iter_mut()) {
                    let LayerCache::Dense { k, v } = cache else {
                        bail!("dense session holds a latent cache");
                    };
                    x = layer.forward_cached(x, m.n_heads, true, k, v);
                }
                tied_head(&last_only(x), &m.lnf_g, &m.lnf_b, &m.head)
            }
            LoadedModel::Latent(m) => {
                check_seq_len(pos0 + tokens.len(), m.pos_emb.rows())?;
                let mut x = embed_tokens(&m.tok_emb, &m.pos_emb, tokens,
                                         pos0);
                for (layer, cache) in
                    m.layers.iter().zip(self.state.layers.iter_mut()) {
                    let LayerCache::Latent { ck, cv } = cache else {
                        bail!("latent session holds a dense cache");
                    };
                    x = layer.forward_cached(x, m.n_heads, m.d_h, ck, cv);
                }
                tied_head(&last_only(x), &m.lnf_g, &m.lnf_b, &m.head)
            }
            LoadedModel::Mm(_) => bail!("multimodal session is unreachable"),
        };
        self.state.advance(tokens.len());
        Ok(logits)
    }

    fn forward_new(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let logits = self.forward_rows(tokens, false)?;
        let mut out = Vec::new();
        row_f32_into(logits.row(0), &mut out);
        Ok(out)
    }
}

/// Convert one f64 logits row into a caller-owned f32 buffer: cleared,
/// exact-capacity reserved, refilled. The hot loops hand in a recycled
/// buffer (the scheduler's per-sequence logits vec, the fused step's
/// out slots), so steady-state decoding does this conversion with zero
/// allocations — the old `.iter().map(|&v| v as f32).collect()` paid a
/// fresh vocab-sized `Vec` per token per sequence.
fn row_f32_into(row: &[f64], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(row.len());
    out.extend(row.iter().map(|&v| v as f32));
}

impl DecodeSession for RefDecodeSession {
    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if self.state.cached_tokens() != 0 {
            bail!("session already prefilled ({} tokens cached)",
                  self.state.cached_tokens());
        }
        if tokens.is_empty() {
            bail!("cannot prefill an empty prompt");
        }
        self.forward_new(tokens).context("prefill")
    }

    fn step(&mut self, token: i32) -> Result<Vec<f32>> {
        if self.state.cached_tokens() == 0 {
            bail!("step before prefill — feed the prompt first");
        }
        self.forward_new(&[token]).context("decode step")
    }

    /// Chunked append: one multi-row forward instead of `tokens.len()`
    /// single-row passes — the scheduler's prefill chunks ride this.
    /// Bit-identical to looping [`DecodeSession::step`] (see
    /// [`RefDecodeSession::forward_rows`]).
    fn step_many(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        if self.state.cached_tokens() == 0 {
            bail!("step_many before prefill — feed the prompt first");
        }
        let logits = self.forward_rows(tokens, true)
            .context("decode step_many")?;
        Ok((0..logits.rows())
            .map(|i| {
                let mut out = Vec::new();
                row_f32_into(logits.row(i), &mut out);
                out
            })
            .collect())
    }

    /// Allocation-free step: identical arithmetic and errors to
    /// [`DecodeSession::step`], but the f32 logits land in a recycled
    /// caller buffer instead of a fresh `Vec` per token.
    fn step_into(&mut self, token: i32, out: &mut Vec<f32>) -> Result<()> {
        if self.state.cached_tokens() == 0 {
            bail!("step before prefill — feed the prompt first");
        }
        let logits = self.forward_rows(&[token], false)
            .context("decode step")?;
        row_f32_into(logits.row(0), out);
        Ok(())
    }

    /// Opt in to the fused multi-session step
    /// ([`fused_step_sessions`]) — the batched state downcasts through
    /// this to group same-model sessions into one weight pass.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn cached_tokens(&self) -> usize {
        self.state.cached_tokens()
    }

    fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    fn cache_kind(&self) -> CacheKind {
        self.kind
    }

    fn n_layers(&self) -> usize {
        self.state.layers.len()
    }

    fn cache_elements(&self) -> usize {
        self.state.cache_elements()
    }

    fn export_prefix(&self, tokens: usize) -> Result<PrefixSnapshot> {
        if tokens > self.state.cached_tokens() {
            bail!("export_prefix: {} tokens requested, {} cached",
                  tokens, self.state.cached_tokens());
        }
        Ok(PrefixSnapshot {
            tokens,
            layers: self.state.layers.iter()
                .map(|l| l.slice_tokens(0, tokens))
                .collect(),
        })
    }

    fn adopt_prefix(&mut self, prefix: &PrefixSnapshot) -> Result<()> {
        if prefix.tokens > self.max_tokens {
            bail!("adopt_prefix: {} tokens exceeds the positional table \
                   ({} max)", prefix.tokens, self.max_tokens);
        }
        self.state.adopt_prefix(prefix).context("adopt prefix")
    }
}

// ---------------------------------------------------------------------------
// Fused multi-session decode step
// ---------------------------------------------------------------------------

/// Reusable scratch for the fused step, owned by the worker's
/// [`crate::runtime::decode::BatchedDecodeState`] (opaquely, as
/// `Box<dyn Any>`) so the hot loop stops allocating the stacked
/// activation and context matrices on every scheduler iteration. The
/// buffers are fully overwritten before every read, so reuse never
/// leaks one iteration's values into the next.
pub struct FusedWorkspace {
    /// stacked single-token activations [N, d]
    x: Matrix,
    /// per-layer attention fan-in [N, d_attn]
    ctx: Matrix,
}

impl Default for FusedWorkspace {
    fn default() -> FusedWorkspace {
        FusedWorkspace {
            x: Matrix::zeros(0, 0),
            ctx: Matrix::zeros(0, 0),
        }
    }
}

/// Token + positional embedding of one token at absolute position `pos`,
/// written straight into a workspace row — the same `e[j] + p[j]` sum
/// [`embed_tokens`] computes, without the per-call Matrix.
fn embed_row_into(tok_emb: &Matrix, pos_emb: &Matrix, tok: i32, pos: usize,
                  row: &mut [f64]) {
    let e = tok_emb.row(clamp_token(tok, tok_emb.rows()));
    let p = pos_emb.row(pos.min(pos_emb.rows() - 1));
    for (o, (ev, pv)) in row.iter_mut().zip(e.iter().zip(p)) {
        *o = ev + pv;
    }
}

/// One fused decode step across N live sessions: stack each session's
/// single token into one [N, d] activation matrix, run every
/// weight-side GEMM (LN + QKV/latent projections, MLP, final LN + tied
/// head) ONCE over all N rows through the [`PackedMat`] kernels, and
/// fan out only the attention cache phase per sequence against its own
/// [`LayerCache`] at its own position. Per-row results are bit-identical
/// to N separate [`DecodeSession::step`] calls because every weight-side
/// kernel computes each output row independently in the same k-order and
/// attention never crosses sequences.
///
/// Returns `None` — with NO session state mutated — whenever the batch
/// cannot fuse: a non-ref session in the mix, different models, a
/// session that is un-prefilled or out of positional-table capacity.
/// The caller then falls back to the per-session loop, which also owns
/// all error reporting (so errors stay identical to unfused stepping).
pub(crate) fn fused_step_sessions(
    sessions: &mut [&mut dyn DecodeSession],
    tokens: &[i32],
    outs: &mut [Vec<f32>],
    ws_slot: &mut Option<Box<dyn std::any::Any>>,
) -> Option<()> {
    if sessions.len() != tokens.len() || sessions.len() != outs.len() {
        return None;
    }
    let mut refs: Vec<&mut RefDecodeSession> =
        Vec::with_capacity(sessions.len());
    for s in sessions.iter_mut() {
        refs.push(s.as_any_mut()?.downcast_mut::<RefDecodeSession>()?);
    }
    let model = refs.first()?.model.clone();
    if refs.iter().any(|r| !std::sync::Arc::ptr_eq(&r.model, &model)) {
        return None;
    }
    // every session must be mid-decode with room for one more token —
    // anything else (prefill pending, table exhausted) would error, and
    // the fallback loop reports those errors per slot exactly as before
    if refs.iter().any(|r| {
        let pos = r.state.cached_tokens();
        pos == 0 || pos + 1 > r.max_tokens
    }) {
        return None;
    }
    if matches!(&*model, LoadedModel::Mm(_)) {
        return None;
    }
    let fresh = match ws_slot.as_ref() {
        Some(b) => !b.is::<FusedWorkspace>(),
        None => true,
    };
    if fresh {
        *ws_slot = Some(Box::<FusedWorkspace>::default());
    }
    let ws = ws_slot.as_mut()?.downcast_mut::<FusedWorkspace>()?;
    match &*model {
        LoadedModel::Dense(m) => fused_dense(m, &mut refs, tokens, outs, ws),
        LoadedModel::Latent(m) => {
            fused_latent(m, &mut refs, tokens, outs, ws)
        }
        LoadedModel::Mm(_) => unreachable!("checked above"),
    }
    Some(())
}

/// Hand a workspace matrix out for this iteration, (re)shaping only when
/// the live-set size changed. Contents are garbage by contract — every
/// row is overwritten before it is read.
fn take_scratch(slot: &mut Matrix, rows: usize, cols: usize) -> Matrix {
    let m = std::mem::replace(slot, Matrix::zeros(0, 0));
    if m.rows() == rows && m.cols() == cols {
        m
    } else {
        Matrix::zeros(rows, cols)
    }
}

fn fused_dense(m: &DenseModel, sess: &mut [&mut RefDecodeSession],
               tokens: &[i32], outs: &mut [Vec<f32>],
               ws: &mut FusedWorkspace) {
    let n = sess.len();
    let mut x = take_scratch(&mut ws.x, n, m.tok_emb.cols());
    for (i, (s, &tok)) in sess.iter().zip(tokens).enumerate() {
        embed_row_into(&m.tok_emb, &m.pos_emb, tok,
                       s.state.cached_tokens(), x.row_mut(i));
    }
    let mut ctx = std::mem::replace(&mut ws.ctx, Matrix::zeros(0, 0));
    for (li, layer) in m.layers.iter().enumerate() {
        let layout = layer.wq.layout().name();
        // weight phase: one GEMM pass over all N rows
        let t0 = profile::phase_start();
        let (q, knew, vnew) = layer.attn_weight_phase(&x);
        profile::phase_end(t0, "dense", "attn_weight", layout);
        if ctx.rows() != n || ctx.cols() != q.cols() {
            ctx = Matrix::zeros(n, q.cols());
        }
        // cache phase: per-sequence attention at each one's own position
        let t0 = profile::phase_start();
        for (i, s) in sess.iter_mut().enumerate() {
            let LayerCache::Dense { k, v } = &mut s.state.layers[li] else {
                unreachable!("dense session cache kind is pinned at open");
            };
            let c = layer.attn_cache_phase(
                &q.slice_rows(i, i + 1), &knew.slice_rows(i, i + 1),
                &vnew.slice_rows(i, i + 1), m.n_heads, true, k, v);
            ctx.row_mut(i).copy_from_slice(c.row(0));
        }
        profile::phase_end(t0, "dense", "attn_cache", layout);
        let t0 = profile::phase_start();
        x = layer.finish_phase(x, &ctx);
        profile::phase_end(t0, "dense", "finish", layout);
    }
    let logits = tied_head(&x, &m.lnf_g, &m.lnf_b, &m.head);
    for (i, (s, out)) in sess.iter_mut().zip(outs.iter_mut()).enumerate() {
        s.state.advance(1);
        row_f32_into(logits.row(i), out);
    }
    ws.x = x;
    ws.ctx = ctx;
}

fn fused_latent(m: &LatentModel, sess: &mut [&mut RefDecodeSession],
                tokens: &[i32], outs: &mut [Vec<f32>],
                ws: &mut FusedWorkspace) {
    let n = sess.len();
    let mut x = take_scratch(&mut ws.x, n, m.tok_emb.cols());
    for (i, (s, &tok)) in sess.iter().zip(tokens).enumerate() {
        embed_row_into(&m.tok_emb, &m.pos_emb, tok,
                       s.state.cached_tokens(), x.row_mut(i));
    }
    let mut ctx = std::mem::replace(&mut ws.ctx, Matrix::zeros(0, 0));
    let d_attn = m.n_heads * m.d_h;
    for (li, layer) in m.layers.iter().enumerate() {
        let layout = layer.aq.layout().name();
        let t0 = profile::phase_start();
        let (q, cknew, cvnew) = layer.attn_weight_phase(&x);
        profile::phase_end(t0, "latent", "attn_weight", layout);
        if ctx.rows() != n || ctx.cols() != d_attn {
            ctx = Matrix::zeros(n, d_attn);
        }
        let t0 = profile::phase_start();
        for (i, s) in sess.iter_mut().enumerate() {
            let LayerCache::Latent { ck, cv } = &mut s.state.layers[li]
            else {
                unreachable!("latent session cache kind is pinned at open");
            };
            let c = layer.attn_cache_phase(
                &q.slice_rows(i, i + 1), &cknew.slice_rows(i, i + 1),
                &cvnew.slice_rows(i, i + 1), m.n_heads, m.d_h, ck, cv);
            ctx.row_mut(i).copy_from_slice(c.row(0));
        }
        profile::phase_end(t0, "latent", "attn_cache", layout);
        let t0 = profile::phase_start();
        x = layer.finish_phase(x, &ctx);
        profile::phase_end(t0, "latent", "finish", layout);
    }
    let logits = tied_head(&x, &m.lnf_g, &m.lnf_b, &m.head);
    for (i, (s, out)) in sess.iter_mut().zip(outs.iter_mut()).enumerate() {
        s.state.advance(1);
        row_f32_into(logits.row(i), out);
    }
    ws.x = x;
    ws.ctx = ctx;
}

/// Buffer length must match the declared shape — callers can build
/// arbitrary [`ParamValue`]s, and a short buffer would otherwise panic at
/// the lane slicing below instead of returning an error.
fn check_len(shape: &[usize], len: usize, what: &str) -> Result<()> {
    let want: usize = shape.iter().product();
    if len != want {
        bail!("{what}: shape {shape:?} implies {want} elements, buffer \
               has {len}");
    }
    Ok(())
}

fn tokens_2d(p: &ParamValue) -> Result<(usize, usize, &[i32])> {
    match p {
        ParamValue::I32 { shape, data } if shape.len() == 2 => {
            check_len(shape, data.len(), "tokens")?;
            Ok((shape[0], shape[1], data))
        }
        other => bail!("expected i32 [b, t] tokens input, got {:?}",
                       other.shape()),
    }
}

fn lens_1d(p: &ParamValue) -> Result<&[i32]> {
    match p {
        ParamValue::I32 { shape, data } if shape.len() == 1 => {
            check_len(shape, data.len(), "lens")?;
            Ok(data)
        }
        other => bail!("expected i32 [b] lens input, got {:?}", other.shape()),
    }
}

fn images_3d(p: &ParamValue) -> Result<(usize, usize, &[f32])> {
    match p {
        ParamValue::F32 { shape, data } if shape.len() == 3 => {
            check_len(shape, data.len(), "images")?;
            Ok((shape[0], shape[1] * shape[2], data))
        }
        other => bail!("expected f32 [b, h, w] images input, got {:?}",
                       other.shape()),
    }
}

fn want_leading(leading: &[ParamValue], n: usize, prog: &str) -> Result<()> {
    if leading.len() != n {
        bail!("{prog}: expected {n} leading input(s), got {}", leading.len());
    }
    Ok(())
}

/// Next-token logits row per lane (python model.step_logits). The lens
/// vector must cover every token lane — a short one would silently decode
/// from padding where the PJRT program signature would reject the shape.
fn step_rows(logits_of: impl Fn(&[i32]) -> Matrix, b: usize, t: usize,
             tokens: &[i32], lens: &[i32]) -> Result<Vec<f32>> {
    if lens.len() != b {
        bail!("step: lens has {} entries for a batch of {b}", lens.len());
    }
    let mut out = Vec::new();
    for lane in 0..b {
        let seq = &tokens[lane * t..(lane + 1) * t];
        let logits = logits_of(seq);
        let idx = ((lens[lane] - 1).max(0) as usize)
            .min(t.saturating_sub(1));
        out.extend(logits.row(idx).iter().map(|&v| v as f32));
    }
    Ok(out)
}

impl Executable for RefExecutable {
    fn execute(&self, leading: &[ParamValue], weights: &Weights,
               _weight_order: &[String]) -> Result<Vec<f32>> {
        match &self.kind {
            RefProgram::Score(_) => {
                want_leading(leading, 1, "score")?;
                let (b, t, tokens) = tokens_2d(&leading[0])?;
                let loaded = self.loaded(weights)?;
                let LoadedModel::Dense(model) = &*loaded else {
                    bail!("score: cached model kind mismatch");
                };
                check_seq_len(t, model.pos_emb.rows())?;
                let mut out = Vec::with_capacity(b);
                for lane in 0..b {
                    let seq = &tokens[lane * t..(lane + 1) * t];
                    out.push(mean_nll(&model.forward(seq), seq) as f32);
                }
                Ok(out)
            }
            RefProgram::Step(_) => {
                want_leading(leading, 2, "step")?;
                let (b, t, tokens) = tokens_2d(&leading[0])?;
                let lens = lens_1d(&leading[1])?;
                let loaded = self.loaded(weights)?;
                let LoadedModel::Dense(model) = &*loaded else {
                    bail!("step: cached model kind mismatch");
                };
                check_seq_len(t, model.pos_emb.rows())?;
                step_rows(|seq| model.forward(seq), b, t, tokens, lens)
            }
            RefProgram::LatentScore(_) => {
                want_leading(leading, 1, "latent_score")?;
                let (b, t, tokens) = tokens_2d(&leading[0])?;
                let loaded = self.loaded(weights)?;
                let LoadedModel::Latent(model) = &*loaded else {
                    bail!("latent_score: cached model kind mismatch");
                };
                check_seq_len(t, model.pos_emb.rows())?;
                let mut out = Vec::with_capacity(b);
                for lane in 0..b {
                    let seq = &tokens[lane * t..(lane + 1) * t];
                    out.push(mean_nll(&model.forward(seq), seq) as f32);
                }
                Ok(out)
            }
            RefProgram::LatentStep(_) => {
                want_leading(leading, 2, "latent_step")?;
                let (b, t, tokens) = tokens_2d(&leading[0])?;
                let lens = lens_1d(&leading[1])?;
                let loaded = self.loaded(weights)?;
                let LoadedModel::Latent(model) = &*loaded else {
                    bail!("latent_step: cached model kind mismatch");
                };
                check_seq_len(t, model.pos_emb.rows())?;
                step_rows(|seq| model.forward(seq), b, t, tokens, lens)
            }
            RefProgram::MmScore(cfg) => {
                want_leading(leading, 2, "mm_score")?;
                let (b, img_hw, images) = images_3d(&leading[0])?;
                let (bt, text_len, tokens) = tokens_2d(&leading[1])?;
                if bt != b {
                    bail!("mm_score: image batch {b} != token batch {bt}");
                }
                if text_len != cfg.text_len {
                    bail!("mm_score: tokens are [.., {text_len}] but the \
                           manifest says text_len={}", cfg.text_len);
                }
                // check both image dims, not just the pixel count: an
                // [b, 8, 32] tensor has the right count but the wrong row
                // stride and would patchify into garbage silently
                let ishape = leading[0].shape();
                if ishape[1] != cfg.vision.img || ishape[2] != cfg.vision.img {
                    bail!("mm_score: images are [.., {}, {}] but the \
                           manifest vision config says img={}",
                          ishape[1], ishape[2], cfg.vision.img);
                }
                let loaded = self.loaded(weights)?;
                let LoadedModel::Mm(model) = &*loaded else {
                    bail!("mm_score: cached model kind mismatch");
                };
                let mut out = Vec::with_capacity(b * cfg.n_answers);
                for lane in 0..b {
                    let im = &images[lane * img_hw..(lane + 1) * img_hw];
                    let tk = &tokens[lane * text_len..(lane + 1) * text_len];
                    let logits = model.forward(im, tk);
                    out.extend(logits.iter().map(|&v| v as f32));
                }
                Ok(out)
            }
        }
    }

    fn open_session(&self, weights: &Weights)
                    -> Result<Box<dyn DecodeSession>> {
        // only the decode families carry the (tokens, lens) signature a
        // session replaces; scoring/multimodal programs have no
        // incremental semantics
        let family = match &self.kind {
            RefProgram::Step(_) | RefProgram::LatentStep(_) => None,
            RefProgram::Score(_) => Some("score"),
            RefProgram::LatentScore(_) => Some("latent_score"),
            RefProgram::MmScore(_) => Some("mm_score"),
        };
        if let Some(f) = family {
            bail!("{f} programs do not support decode sessions \
                   (use a step_* / latent_step_* program)");
        }
        Ok(Box::new(RefDecodeSession::open(self.loaded(weights)?)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::tests_support::random_weights;
    use crate::model::config::MiniConfig;

    const TINY: MiniConfig = MiniConfig {
        name: "tiny", vocab: 40, d: 16, n_layers: 2, n_heads: 2,
        d_i: 32, max_len: 24,
    };

    fn tiny_cfg() -> LmCfg {
        LmCfg { vocab: TINY.vocab, d: TINY.d, n_layers: TINY.n_layers,
                n_heads: TINY.n_heads }
    }

    #[test]
    fn zero_model_scores_uniform_nll() {
        // all-zero weights ⇒ logits identically 0 ⇒ NLL = ln(vocab),
        // an exact analytic anchor for the whole forward pass.
        let mut w = random_weights(&TINY, 1);
        let names: Vec<String> = w.names().cloned().collect();
        for name in names {
            let shape = match w.tensor(&name).unwrap() {
                Tensor::F32 { shape, .. } => shape.clone(),
                Tensor::I32 { .. } => continue,
            };
            let n: usize = shape.iter().product();
            let fill = if name.ends_with(".g") { 1.0 } else { 0.0 };
            w.set_tensor(&name, Tensor::F32 {
                shape,
                data: vec![fill; n],
            });
        }
        let model = DenseModel::load(&w, &tiny_cfg()).unwrap();
        let tokens: Vec<i32> = (0..12).map(|i| i % TINY.vocab as i32)
            .collect();
        let nll = mean_nll(&model.forward(&tokens), &tokens);
        let want = (TINY.vocab as f64).ln();
        assert!((nll - want).abs() < 1e-9, "nll {nll} vs ln(V) {want}");
    }

    #[test]
    fn causal_mask_isolates_future_tokens() {
        // logits at position k must not depend on tokens after k.
        let w = random_weights(&TINY, 2);
        let model = DenseModel::load(&w, &tiny_cfg()).unwrap();
        let a: Vec<i32> = (0..10).map(|i| (i * 3) % 40).collect();
        let mut b = a.clone();
        for v in b.iter_mut().skip(6) {
            *v = 39 - *v;
        }
        let la = model.forward(&a);
        let lb = model.forward(&b);
        for i in 0..6 {
            for j in 0..TINY.vocab {
                assert!((la[(i, j)] - lb[(i, j)]).abs() < 1e-9,
                        "row {i} differs");
            }
        }
        // and positions ≥ 6 DO see the change
        let mut any = 0.0f64;
        for j in 0..TINY.vocab {
            any += (la[(7, j)] - lb[(7, j)]).abs();
        }
        assert!(any > 1e-9, "future rows should differ");
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut s = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64 * 0.3);
        softmax_rows(&mut s, Some(0));
        for i in 0..4 {
            let sum: f64 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
            for j in (i + 1)..4 {
                assert_eq!(s[(i, j)], 0.0, "causal leak at ({i},{j})");
            }
        }
    }

    #[test]
    fn layer_norm_matches_definition() {
        let x = Matrix::from_fn(2, 4, |i, j| (i as f64 + 1.0) * j as f64);
        let g = vec![2.0; 4];
        let b = vec![0.5; 4];
        let y = layer_norm(&x, &g, &b);
        for i in 0..2 {
            let mean: f64 = y.row(i).iter().sum::<f64>() / 4.0;
            // g uniform, b uniform ⇒ normalized rows keep mean b
            assert!((mean - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn cached_attention_matches_full_window_exactly() {
        // one query row against a growing K/V prefix must reproduce the
        // full causal attention row-for-row, bit for bit — the identity
        // the whole incremental decode path rests on.
        let mut rng = crate::util::rng::Rng::new(17);
        let (t, d, h) = (6, 8, 2);
        let q = rng.normal_matrix(t, d);
        let k = rng.normal_matrix(t, d);
        let v = rng.normal_matrix(t, d);
        let full = mha(&q, &k, &v, h, Some(0));
        for i in 0..t {
            let qi = q.slice_rows(i, i + 1);
            let kp = k.slice_rows(0, i + 1);
            let vp = v.slice_rows(0, i + 1);
            let step = mha(&qi, &kp, &vp, h, Some(i));
            assert_eq!(step.row(0), full.row(i), "row {i} diverged");
        }
    }

    #[test]
    fn augmented_products_match_explicit_ones_column() {
        let mut rng = crate::util::rng::Rng::new(23);
        let x = rng.normal_matrix(3, 4);
        let a = rng.normal_matrix(5, 6);
        let append_ones = |m: &Matrix| {
            let mut out = Matrix::zeros(m.rows(), m.cols() + 1);
            for i in 0..m.rows() {
                out.row_mut(i)[..m.cols()].copy_from_slice(m.row(i));
                out[(i, m.cols())] = 1.0;
            }
            out
        };
        let want = append_ones(&x).matmul(&a);
        assert!(matmul_ones_a(&x, &a).max_abs_diff(&want) < 1e-12);

        let b = rng.normal_matrix(7, 5);
        let want = append_ones(&x).matmul_bt(&b);
        assert!(matmul_ones_bt(&x, &b).max_abs_diff(&want) < 1e-12);

        let xa = rng.normal_matrix(3, 5); // already-augmented side
        let braw = rng.normal_matrix(7, 4);
        let want = xa.matmul_bt(&append_ones(&braw));
        assert!(matmul_bt_ones(&xa, &braw).max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn fast_ones_kernel_matches_exact_order_kernel() {
        // the blocked variant used on packed layouts reorders the f64
        // accumulation, so equality is within rounding noise, not bitwise
        let mut rng = crate::util::rng::Rng::new(29);
        for &(m, r, n) in &[(1usize, 7usize, 5usize), (3, 8, 9), (2, 4, 4)] {
            let x = rng.normal_matrix(m, r + 1);
            let b = rng.normal_matrix(n, r);
            let exact = matmul_bt_ones(&x, &b);
            let fast = matmul_bt_ones_fast(&x, &b);
            assert!(fast.max_abs_diff(&exact) < 1e-12,
                    "blocked ones-kernel drifted at ({m},{r},{n})");
        }
    }

    #[test]
    fn parse_program_rejects_unknown_families() {
        let manifest = Value::obj(vec![]);
        assert!(parse_program("gibberish", &manifest).is_err());
        assert!(parse_program("score_missing", &manifest).is_err());
    }

    #[test]
    fn executable_memoizes_per_weight_set() {
        let exe = RefExecutable {
            kind: RefProgram::Score(tiny_cfg()),
            cache: std::sync::Mutex::new(ModelCache::new()),
        };
        let w = random_weights(&TINY, 5);
        let tokens = ParamValue::I32 {
            shape: vec![1, 8],
            data: (0..8).collect(),
        };
        let out1 = exe.execute(&[tokens.clone()], &w, &[]).unwrap();
        assert!(matches!(
                    exe.cache.lock().unwrap().get(&w.cache_id())
                        .map(|m| &**m),
                    Some(LoadedModel::Dense(_))),
                "first execute must populate the cache");
        let out2 = exe.execute(&[tokens.clone()], &w, &[]).unwrap();
        assert_eq!(out1, out2, "cache hit must not change results");
        // a mutated weight set carries a fresh id → a second entry, so
        // two variants alternating on one program both stay hot
        let mut w2 = w.clone();
        let bump = vec![0.5f64; TINY.d];
        w2.set_bias("lnf.b", &bump);
        let _ = exe.execute(&[tokens.clone()], &w2, &[]).unwrap();
        {
            let g = exe.cache.lock().unwrap();
            assert!(g.contains_key(&w.cache_id()));
            assert!(g.contains_key(&w2.cache_id()));
            assert_eq!(g.len(), 2);
        }
        // the cap bounds the map: a burst of fresh weight sets resets it
        for seed in 100..100 + (MODEL_CACHE_CAP as u64) {
            let wn = random_weights(&TINY, seed);
            let _ = exe.execute(&[tokens.clone()], &wn, &[]).unwrap();
        }
        assert!(exe.cache.lock().unwrap().len() <= MODEL_CACHE_CAP,
                "cache must stay bounded");
    }

    #[test]
    fn short_buffers_error_instead_of_panicking() {
        let bad = ParamValue::I32 { shape: vec![4, 12], data: vec![0; 10] };
        assert!(tokens_2d(&bad).is_err());
        let bad_img = ParamValue::F32 { shape: vec![2, 16, 16],
                                        data: vec![0.0; 100] };
        assert!(images_3d(&bad_img).is_err());
        let bad_lens = ParamValue::I32 { shape: vec![3], data: vec![1] };
        assert!(lens_1d(&bad_lens).is_err());
    }

    #[test]
    fn adopted_prefix_continues_bit_identical_to_cold_prefill() {
        // the prefix-cache identity: export the first-k cache rows from
        // one session, adopt them into a fresh one, feed the remainder —
        // every subsequent logit row must match the cold session exactly.
        let w = random_weights(&TINY, 31);
        let model = std::sync::Arc::new(LoadedModel::Dense(
            DenseModel::load(&w, &tiny_cfg()).unwrap()));
        let prompt: Vec<i32> = (0..8).map(|i| (i * 5 + 2) % 40).collect();

        let mut cold = RefDecodeSession::open(model.clone()).unwrap();
        let cold_logits = cold.prefill(&prompt).unwrap();

        // donor caches the full prompt; export only the first 6 tokens
        let mut donor = RefDecodeSession::open(model.clone()).unwrap();
        donor.prefill(&prompt).unwrap();
        let snap = donor.export_prefix(6).unwrap();
        assert_eq!(snap.tokens, 6);

        let mut warm = RefDecodeSession::open(model.clone()).unwrap();
        warm.adopt_prefix(&snap).unwrap();
        assert_eq!(warm.cached_tokens(), 6);
        // feed the uncached tail; the last row is the prefill logits
        let rows = warm.step_many(&prompt[6..]).unwrap();
        assert_eq!(rows.last().unwrap(), &cold_logits);

        // and the decoded continuation stays identical too
        assert_eq!(warm.step(7).unwrap(), cold.step(7).unwrap());

        // exporting more than is cached refuses
        assert!(warm.export_prefix(100).is_err());
        // adopting into a non-empty session refuses
        assert!(warm.adopt_prefix(&snap).is_err());
    }
}
