//! Symmetric eigendecomposition.
//!
//! This is the workhorse of the whole compression suite: Algorithm 1's
//! `RightSingular_r[·]` calls are top-k eigenvector extractions of
//! symmetric PSD accumulation matrices, and `sqrtm`/`invsqrtm` (the optimal
//! pre-conditioner, paper §3.2) are built on it.
//!
//! §Perf: the production path [`eigh`] is Householder tridiagonalization +
//! implicit-shift QL (EISPACK tred2/tql2) — ~40× faster than the cyclic
//! Jacobi reference at n=256. [`eigh_jacobi`] is kept as the slow exact
//! reference and cross-checked in tests.

use super::matrix::Matrix;

/// Eigendecomposition of a symmetric matrix: `a ≈ V diag(w) Vᵀ`.
/// Returns (eigenvalues ascending, eigenvectors as columns of V).
pub fn eigh(a: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows(), a.cols(), "eigh needs square input");
    let n = a.rows();
    if n == 0 {
        return (Vec::new(), Matrix::zeros(0, 0));
    }
    if n <= 4 {
        return eigh_jacobi(a); // tiny: Jacobi is simplest and exact
    }
    let mut z = a.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut z, &mut d, &mut e);
    // §Perf: tql2's Givens accumulation touches two COLUMNS per rotation —
    // strided in row-major storage. Rotating rows of the transpose keeps
    // both operands contiguous (~2-3× at n ≥ 256).
    let mut zt = z.transpose();
    tql2_rows(&mut zt, &mut d, &mut e);
    // sort ascending (tql2 output is not guaranteed sorted)
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[i].total_cmp(&d[j]));
    let w: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut v = Matrix::zeros(n, n);
    for (jnew, &jold) in idx.iter().enumerate() {
        for i in 0..n {
            v[(i, jnew)] = zt[(jold, i)];
        }
    }
    (w, v)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form,
/// accumulating the orthogonal transform in `a` (EISPACK tred2).
fn tred2(a: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += a[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = a[(i, l)];
            } else {
                for k in 0..=l {
                    a[(i, k)] /= scale;
                    h += a[(i, k)] * a[(i, k)];
                }
                let f = a[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[(i, l)] = f - g;
                let mut f_acc = 0.0;
                for j in 0..=l {
                    a[(j, i)] = a[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a[(j, k)] * a[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += a[(k, j)] * a[(i, k)];
                    }
                    e[j] = g / h;
                    f_acc += e[j] * a[(i, j)];
                }
                let hh = f_acc / (h + h);
                for j in 0..=l {
                    let f = a[(i, j)];
                    let gj = e[j] - hh * f;
                    e[j] = gj;
                    for k in 0..=j {
                        let delta = f * e[k] + gj * a[(i, k)];
                        a[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = a[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += a[(i, k)] * a[(k, j)];
                }
                for k in 0..i {
                    let delta = g * a[(k, i)];
                    a[(k, j)] -= delta;
                }
            }
        }
        d[i] = a[(i, i)];
        a[(i, i)] = 1.0;
        for j in 0..i {
            a[(j, i)] = 0.0;
            a[(i, j)] = 0.0;
        }
    }
}

/// Implicit-shift QL iteration for a symmetric tridiagonal matrix with
/// eigenvector accumulation (EISPACK tql2), operating on the TRANSPOSED
/// transform (eigenvectors as rows) so each Givens rotation is two
/// contiguous row updates. d = diagonal, e = subdiagonal (e[0] unused).
fn tql2_rows(zt: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a negligible subdiagonal element
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 64, "tql2: no convergence");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate the rotation: rows i and i+1 of zt, both
                // contiguous in memory
                {
                    let (row_i, row_i1) = {
                        let base = zt.data_mut();
                        let (lo, hi) = base.split_at_mut((i + 1) * n);
                        (&mut lo[i * n..], &mut hi[..n])
                    };
                    for k in 0..n {
                        let f2 = row_i1[k];
                        row_i1[k] = s * row_i[k] + c * f2;
                        row_i[k] = c * row_i[k] - s * f2;
                    }
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Cyclic-Jacobi reference implementation (slow, backward-stable).
pub fn eigh_jacobi(a: &Matrix) -> (Vec<f64>, Matrix) {
    assert_eq!(a.rows(), a.cols(), "eigh needs square input");
    let n = a.rows();
    let mut m = a.symmetrize();
    let mut v = Matrix::eye(n);
    let max_sweeps = 64;
    let eps = 1e-14;

    for _sweep in 0..max_sweeps {
        // Frobenius norm of the strict upper triangle.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let scale: f64 = m.frob2().max(1e-300);
        if off <= eps * eps * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                // threshold Jacobi (§Perf): skip rotations already below
                // the final relative accuracy — cuts late-sweep work ~n²
                let scale = (m[(p, p)].abs() * m[(q, q)].abs()).sqrt();
                if apq.abs() <= 1e-13 * scale.max(1e-300) {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum()
                    / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation G(p,q,θ) on both sides: m = Gᵀ m G.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut idx: Vec<usize> = (0..n).collect();
    let w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&i, &j| w[i].total_cmp(&w[j]));
    let wv: Vec<f64> = idx.iter().map(|&i| w[i]).collect();
    let vv = v.select_cols(&idx);
    (wv, vv)
}

/// Top-k eigenvectors of a symmetric matrix, returned as ROWS (k×n) —
/// this is Algorithm 1's `RightSingular_k[·]` on a PSD accumulation.
pub fn topk_eigvecs(a: &Matrix, k: usize) -> Matrix {
    let (w, v) = eigh(a);
    let n = w.len();
    let k = k.min(n);
    // eigenvalues ascend; take the last k, largest first.
    let idx: Vec<usize> = (0..k).map(|i| n - 1 - i).collect();
    v.select_cols(&idx).transpose()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct(w: &[f64], v: &Matrix) -> Matrix {
        let n = w.len();
        let mut s = Matrix::zeros(n, n);
        for i in 0..n {
            s[(i, i)] = w[i];
        }
        v.matmul(&s).matmul_bt(v)
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 3, 8, 24] {
            let g = rng.normal_matrix(n, n);
            let a = g.matmul_bt(&g); // PSD
            let (w, v) = eigh(&a);
            assert!(reconstruct(&w, &v).max_abs_diff(&a) < 1e-8 * (n as f64),
                    "n={n}");
            // orthonormal columns
            let vtv = v.matmul_at(&v);
            assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-9);
            // ascending
            for i in 1..n {
                assert!(w[i] >= w[i - 1] - 1e-9);
            }
        }
    }

    #[test]
    fn eigh_known_values() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (w, _) = eigh(&a);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn topk_rows_orthonormal_and_principal() {
        let mut rng = Rng::new(5);
        let g = rng.normal_matrix(12, 30);
        let a = g.matmul_bt(&g);
        let top = topk_eigvecs(&a, 4); // 4x12
        let tt = top.matmul_bt(&top);
        assert!(tt.max_abs_diff(&Matrix::eye(4)) < 1e-9);
        // Rayleigh quotients should match the top eigenvalues.
        let (w, _) = eigh(&a);
        let r0: f64 = {
            let v: Vec<f64> = top.row(0).to_vec();
            let av = a.matvec(&v);
            v.iter().zip(&av).map(|(x, y)| x * y).sum()
        };
        assert!((r0 - w[11]).abs() < 1e-6 * w[11].abs().max(1.0));
    }
}

#[cfg(test)]
mod tred_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fast_eigh_matches_jacobi_reference() {
        let mut rng = Rng::new(77);
        for n in [5usize, 9, 16, 33, 64] {
            let g = rng.normal_matrix(n, n);
            let a = g.matmul_bt(&g);
            let (wf, vf) = eigh(&a);
            let (wj, _) = eigh_jacobi(&a);
            for (x, y) in wf.iter().zip(&wj) {
                assert!((x - y).abs() < 1e-8 * (1.0 + y.abs()),
                        "n={n}: {x} vs {y}");
            }
            // reconstruction + orthogonality
            let mut s = Matrix::zeros(n, n);
            for i in 0..n {
                s[(i, i)] = wf[i];
            }
            let rec = vf.matmul(&s).matmul_bt(&vf);
            assert!(rec.max_abs_diff(&a) < 1e-7 * (n as f64), "n={n}");
            let vtv = vf.matmul_at(&vf);
            assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn fast_eigh_handles_degenerate() {
        // repeated eigenvalues + zero rows
        let mut a = Matrix::eye(8);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 3.0;
        a[(7, 7)] = 0.0;
        let (w, v) = eigh(&a);
        assert!((w[0] - 0.0).abs() < 1e-12);
        assert!((w[7] - 3.0).abs() < 1e-12);
        let vtv = v.matmul_at(&v);
        assert!(vtv.max_abs_diff(&Matrix::eye(8)) < 1e-10);
    }
}
