//! Higher-level decompositions on top of eigh/svd: PSD square roots (the
//! optimal pre-conditioner P = C^{1/2}, paper §3.2), Moore–Penrose
//! pseudo-inverse, Cholesky, and linear solves.

use super::eig::eigh;
use super::matrix::Matrix;
use super::svd::svd;

/// Symmetric PSD square root via eigendecomposition.
pub fn sqrtm_psd(c: &Matrix) -> Matrix {
    let (w, v) = eigh(c);
    scaled_outer(&v, &w.iter().map(|&x| x.max(0.0).sqrt()).collect::<Vec<_>>())
}

/// (C^{1/2}, C^{-1/2}) from a single eigendecomposition — the root-cov
/// pre-conditioner pair (§Perf: halves the dominant eigh cost).
pub fn sqrt_and_invsqrt_psd(c: &Matrix) -> (Matrix, Matrix) {
    let (w, v) = eigh(c);
    let wmax = w.last().copied().unwrap_or(0.0).max(0.0);
    let roots: Vec<f64> = w.iter().map(|&x| x.max(0.0).sqrt()).collect();
    let invs: Vec<f64> = w.iter()
        .map(|&x| {
            if x > 1e-10 * wmax.max(1.0) {
                1.0 / x.max(0.0).sqrt()
            } else {
                0.0
            }
        })
        .collect();
    (scaled_outer(&v, &roots), scaled_outer(&v, &invs))
}

/// Pseudo-inverse of a symmetric PSD matrix via eigendecomposition
/// (§Perf: much cheaper than the SVD-based general `pinv`).
pub fn pinv_psd(c: &Matrix) -> Matrix {
    let (w, v) = eigh(c);
    let wmax = w.last().copied().unwrap_or(0.0).max(0.0);
    let inv: Vec<f64> = w.iter()
        .map(|&x| if x > 1e-12 * wmax.max(1.0) { 1.0 / x } else { 0.0 })
        .collect();
    scaled_outer(&v, &inv)
}

/// Pseudo-inverse square root of a symmetric PSD matrix.
pub fn invsqrtm_psd(c: &Matrix) -> Matrix {
    let (w, v) = eigh(c);
    let wmax = w.last().copied().unwrap_or(0.0).max(0.0);
    let inv: Vec<f64> = w
        .iter()
        .map(|&x| {
            if x > 1e-10 * wmax.max(1.0) {
                1.0 / x.max(0.0).sqrt()
            } else {
                0.0
            }
        })
        .collect();
    scaled_outer(&v, &inv)
}

/// V diag(s) Vᵀ.
fn scaled_outer(v: &Matrix, s: &[f64]) -> Matrix {
    let n = v.rows();
    let mut vs = v.clone();
    for j in 0..s.len() {
        for i in 0..n {
            vs[(i, j)] *= s[j];
        }
    }
    vs.matmul_bt(v)
}

/// Moore–Penrose pseudo-inverse via SVD.
pub fn pinv(a: &Matrix) -> Matrix {
    let f = svd(a);
    let smax = f.s.first().copied().unwrap_or(0.0);
    let cutoff = 1e-12 * smax.max(1.0);
    // A⁺ = V S⁺ Uᵀ
    let mut v = f.vt.transpose();
    for j in 0..f.s.len() {
        let inv = if f.s[j] > cutoff { 1.0 / f.s[j] } else { 0.0 };
        for i in 0..v.rows() {
            v[(i, j)] *= inv;
        }
    }
    v.matmul_bt(&f.u)
}

/// Cholesky factor L with A = L Lᵀ (lower). Returns None if not PD.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve A X = B for square A (partial-pivot LU). Panics if singular.
pub fn solve(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), a.cols());
    assert_eq!(a.rows(), b.rows());
    let n = a.rows();
    let m = b.cols();
    let mut lu = a.clone();
    let mut x = b.clone();
    for k in 0..n {
        // pivot
        let mut p = k;
        for i in (k + 1)..n {
            if lu[(i, k)].abs() > lu[(p, k)].abs() {
                p = i;
            }
        }
        if lu[(p, k)].abs() < 1e-300 {
            panic!("solve: singular matrix");
        }
        if p != k {
            for j in 0..n {
                let t = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = t;
            }
            for j in 0..m {
                let t = x[(k, j)];
                x[(k, j)] = x[(p, j)];
                x[(p, j)] = t;
            }
        }
        let piv = lu[(k, k)];
        for i in (k + 1)..n {
            let f = lu[(i, k)] / piv;
            if f == 0.0 {
                continue;
            }
            lu[(i, k)] = f;
            for j in (k + 1)..n {
                lu[(i, j)] -= f * lu[(k, j)];
            }
            for j in 0..m {
                x[(i, j)] -= f * x[(k, j)];
            }
        }
    }
    // back substitution
    for k in (0..n).rev() {
        let piv = lu[(k, k)];
        for j in 0..m {
            x[(k, j)] /= piv;
        }
        for i in 0..k {
            let f = lu[(i, k)];
            if f == 0.0 {
                continue;
            }
            for j in 0..m {
                x[(i, j)] -= f * x[(k, j)];
            }
        }
    }
    x
}

/// Activation-aware loss tr[(W−Ŵ) C (W−Ŵ)ᵀ]  (paper Eq 4/35).
pub fn act_loss(w: &Matrix, w_hat: &Matrix, c: &Matrix) -> f64 {
    let d = w.sub(w_hat);
    d.matmul(c).matmul_bt(&d).trace()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sqrtm_squares_back() {
        let mut rng = Rng::new(8);
        let g = rng.normal_matrix(10, 16);
        let c = g.matmul_bt(&g);
        let r = sqrtm_psd(&c);
        assert!(r.matmul(&r).max_abs_diff(&c) < 1e-8);
        assert!(r.max_abs_diff(&r.symmetrize()) < 1e-10);
    }

    #[test]
    fn invsqrtm_whitens() {
        let mut rng = Rng::new(9);
        let g = rng.normal_matrix(8, 24);
        let c = g.matmul_bt(&g);
        let ri = invsqrtm_psd(&c);
        let r = sqrtm_psd(&c);
        // ri * c * ri ≈ I (c is full rank a.s.)
        let w = ri.matmul(&c).matmul(&ri);
        assert!(w.max_abs_diff(&Matrix::eye(8)) < 1e-8);
        // ri ≈ inverse of r
        assert!(ri.matmul(&r).max_abs_diff(&Matrix::eye(8)) < 1e-8);
    }

    #[test]
    fn pinv_moore_penrose() {
        let mut rng = Rng::new(10);
        for (m, n) in [(6, 4), (4, 6), (5, 5)] {
            let a = rng.normal_matrix(m, n);
            let p = pinv(&a);
            // A A⁺ A = A ;  A⁺ A A⁺ = A⁺
            assert!(a.matmul(&p).matmul(&a).max_abs_diff(&a) < 1e-9);
            assert!(p.matmul(&a).matmul(&p).max_abs_diff(&p) < 1e-9);
            // symmetry of projectors
            let ap = a.matmul(&p);
            assert!(ap.max_abs_diff(&ap.transpose()) < 1e-9);
            let pa = p.matmul(&a);
            assert!(pa.max_abs_diff(&pa.transpose()) < 1e-9);
        }
    }

    #[test]
    fn cholesky_roundtrip_and_rejects_indefinite() {
        let mut rng = Rng::new(11);
        let g = rng.normal_matrix(7, 14);
        let c = g.matmul_bt(&g);
        let l = cholesky(&c).unwrap();
        assert!(l.matmul_bt(&l).max_abs_diff(&c) < 1e-9);
        let mut ind = Matrix::eye(3);
        ind[(2, 2)] = -1.0;
        assert!(cholesky(&ind).is_none());
    }

    #[test]
    fn solve_matches_pinv_for_square() {
        let mut rng = Rng::new(12);
        let a = rng.normal_matrix(6, 6);
        let b = rng.normal_matrix(6, 3);
        let x = solve(&a, &b);
        assert!(a.matmul(&x).max_abs_diff(&b) < 1e-9);
    }
}
