//! Row-major f64 dense matrix with the handful of BLAS-3 style kernels the
//! compression algorithms need. The matmul family is cache-blocked and is
//! the §Perf hot path for the rust-side pipeline.
//!
//! Above [`PAR_MIN_FLOPS`] the matmul family parallelizes over row blocks
//! of the output on the global [`Pool`]. Each output row is computed with
//! exactly the serial loop's per-row arithmetic (same k order, same
//! zero-skip), so parallel results are bit-identical to serial at any
//! thread count — the property the compress-pipeline determinism test
//! pins down.

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::util::pool::Pool;

/// Below this many multiply-adds the fork-join overhead dominates; run
/// serially. ~128³.
const PAR_MIN_FLOPS: usize = 2 << 20;

/// Row-parallel execution plan: `Some((pool, block_rows))` when the
/// product is big enough and a multi-thread pool is available. Shared
/// with the packed-layout kernels (tensor/packed.rs).
pub(crate) fn par_plan(out_rows: usize, out_cols: usize, flops: usize)
                       -> Option<(Pool, usize)> {
    if out_rows < 2 || out_cols == 0 || flops < PAR_MIN_FLOPS
        || Pool::in_worker() {
        return None;
    }
    let pool = Pool::global();
    let t = pool.threads();
    if t <= 1 {
        return None;
    }
    // ~4 blocks per thread: dynamic-ish balance with static assignment
    let blocks = (t * 4).min(out_rows);
    Some((pool, out_rows.div_ceil(blocks)))
}

#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// One output row of C = A · B: ikj order with the zero-skip — the
    /// single source of truth for both the serial and parallel paths.
    #[inline]
    fn matmul_row_into(&self, b: &Matrix, i: usize, crow: &mut [f64]) {
        let n = b.cols;
        for k in 0..self.cols {
            let aik = self.data[i * self.cols + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }

    /// C = A · B. ikj loop order (row-major streaming) — the fast path.
    /// Row-block-parallel above [`PAR_MIN_FLOPS`]; bit-identical to the
    /// serial path at any thread count.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape {}x{} @ {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        let mut c = Matrix::zeros(self.rows, b.cols);
        let n = b.cols;
        let flops = self.rows * self.cols * n;
        if let Some((pool, block)) = par_plan(self.rows, n, flops) {
            pool.par_chunks(&mut c.data, block * n, |bi, chunk| {
                for (di, crow) in chunk.chunks_mut(n).enumerate() {
                    self.matmul_row_into(b, bi * block + di, crow);
                }
            });
        } else {
            for i in 0..self.rows {
                let crow = &mut c.data[i * n..(i + 1) * n];
                self.matmul_row_into(b, i, crow);
            }
        }
        c
    }

    /// One output row of C = A · Bᵀ (dot-product form).
    #[inline]
    fn matmul_bt_row_into(&self, b: &Matrix, i: usize, crow: &mut [f64]) {
        let arow = self.row(i);
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = b.row(j);
            let mut s = 0.0;
            for k in 0..self.cols {
                s += arow[k] * brow[k];
            }
            *cv = s;
        }
    }

    /// C = A · Bᵀ — dot-product form, both operands stream row-major.
    /// Row-block-parallel above [`PAR_MIN_FLOPS`].
    pub fn matmul_bt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_bt shape");
        if self.rows == 1 {
            // the T=1 decode step: the matvec-shaped kernel skips the
            // per-row planning/slicing overhead. Same dots in the same k
            // order (matvec's iterator sum folds from 0.0 exactly like
            // matmul_bt_row_into's loop), so this is bit-identical —
            // pinned by single_row_matmul_bt_is_bit_identical.
            return Matrix { rows: 1, cols: b.rows, data: b.matvec(self.row(0)) };
        }
        let mut c = Matrix::zeros(self.rows, b.rows);
        let n = b.rows;
        let flops = self.rows * self.cols * n;
        if let Some((pool, block)) = par_plan(self.rows, n, flops) {
            pool.par_chunks(&mut c.data, block * n, |bi, chunk| {
                for (di, crow) in chunk.chunks_mut(n).enumerate() {
                    self.matmul_bt_row_into(b, bi * block + di, crow);
                }
            });
        } else {
            for i in 0..self.rows {
                let crow = &mut c.data[i * n..(i + 1) * n];
                self.matmul_bt_row_into(b, i, crow);
            }
        }
        c
    }

    /// One output row i of C = Aᵀ · B: k ascending with the zero-skip —
    /// the same per-(i,j) accumulation sequence as the serial k-outer
    /// loop, so the row-parallel path stays bit-identical.
    #[inline]
    fn matmul_at_row_into(&self, b: &Matrix, i: usize, crow: &mut [f64]) {
        let n = b.cols;
        for k in 0..self.rows {
            let aki = self.data[k * self.cols + i];
            if aki == 0.0 {
                continue;
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for j in 0..n {
                crow[j] += aki * brow[j];
            }
        }
    }

    /// C = Aᵀ · B. Row-block-parallel above [`PAR_MIN_FLOPS`]; the serial
    /// path keeps the k-outer streaming order.
    pub fn matmul_at(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_at shape");
        let mut c = Matrix::zeros(self.cols, b.cols);
        let n = b.cols;
        let flops = self.rows * self.cols * n;
        if let Some((pool, block)) = par_plan(self.cols, n, flops) {
            pool.par_chunks(&mut c.data, block * n, |bi, chunk| {
                for (di, crow) in chunk.chunks_mut(n).enumerate() {
                    self.matmul_at_row_into(b, bi * block + di, crow);
                }
            });
            return c;
        }
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = &b.data[k * n..(k + 1) * n];
            for i in 0..self.cols {
                let aki = arow[i];
                if aki == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += aki * brow[j];
                }
            }
        }
        c
    }

    /// y = A · x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                row.iter().zip(x).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    pub fn add(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add_inplace(&mut self, b: &Matrix) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (a, b) in self.data.iter_mut().zip(&b.data) {
            *a += b;
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let data = self.data.iter().map(|v| v * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale_inplace(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Squared Frobenius norm.
    pub fn frob2(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    /// Rows [r0, r1) as a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Append `b`'s rows in place (same width). The grow operation of the
    /// decode KV caches: row-major layout makes this a buffer extend, so
    /// per-token cache growth is O(width) amortized, never a reallocation
    /// of prior tokens' state.
    pub fn push_rows(&mut self, b: &Matrix) {
        assert_eq!(self.cols, b.cols, "push_rows width {} vs {}", self.cols,
                   b.cols);
        self.data.extend_from_slice(&b.data);
        self.rows += b.rows;
    }

    /// Columns [c0, c1) as a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        Matrix::from_fn(self.rows, c1 - c0, |i, j| self[(i, j + c0)])
    }

    /// Gather the given columns.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        Matrix::from_fn(self.rows, idx.len(), |i, j| self[(i, idx[j])])
    }

    /// Stack vertically.
    pub fn vstack(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            assert_eq!(b.cols, cols);
            data.extend_from_slice(&b.data);
        }
        Matrix { rows, cols, data }
    }

    /// Stack horizontally.
    pub fn hstack(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        let cols = blocks.iter().map(|b| b.cols).sum();
        let mut m = Matrix::zeros(rows, cols);
        let mut off = 0;
        for b in blocks {
            assert_eq!(b.rows, rows);
            for i in 0..rows {
                m.row_mut(i)[off..off + b.cols].copy_from_slice(b.row(i));
            }
            off += b.cols;
        }
        m
    }

    pub fn max_abs_diff(&self, b: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&b.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn symmetrize(&self) -> Matrix {
        assert_eq!(self.rows, self.cols);
        Matrix::from_fn(self.rows, self.cols,
                        |i, j| 0.5 * (self[(i, j)] + self[(j, i)]))
    }

    /// Column-token covariance C = (X Xᵀ + λ·tr/d·I)/l (paper Remark 3).
    pub fn covariance(&self, lam_rel: f64) -> Matrix {
        let l = self.cols.max(1) as f64;
        let mut c = self.matmul_bt(self);
        let tr = c.trace() / c.rows.max(1) as f64;
        let lam = lam_rel * tr.max(1e-12);
        for i in 0..c.rows {
            c[(i, i)] += lam;
        }
        c.scale_inplace(1.0 / l);
        c.symmetrize()
    }

    /// Column mean μ = X·1/l.
    pub fn col_mean(&self) -> Vec<f64> {
        let l = self.cols.max(1) as f64;
        (0..self.rows)
            .map(|i| self.row(i).iter().sum::<f64>() / l)
            .collect()
    }

    /// X − μ·1ᵀ.
    pub fn center_cols(&self, mu: &[f64]) -> Matrix {
        assert_eq!(mu.len(), self.rows);
        Matrix::from_fn(self.rows, self.cols, |i, j| self[(i, j)] - mu[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let b = Matrix::from_fn(4, 2, |i, j| (i + j) as f64 * 0.5);
        let c = a.matmul(&b);
        for i in 0..3 {
            for j in 0..2 {
                let mut s = 0.0;
                for k in 0..4 {
                    s += a[(i, k)] * b[(k, j)];
                }
                assert!((c[(i, j)] - s).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn push_rows_grows_from_empty() {
        let mut m = Matrix::zeros(0, 3);
        assert_eq!(m.rows(), 0);
        m.push_rows(&Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f64));
        m.push_rows(&Matrix::from_fn(1, 3, |_, j| 10.0 + j as f64));
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.row(2), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let a = Matrix::from_fn(5, 7, |i, j| ((i * 13 + j * 7) % 11) as f64 - 5.0);
        let b = Matrix::from_fn(7, 4, |i, j| ((i * 5 + j * 3) % 9) as f64 - 4.0);
        let c0 = a.matmul(&b);
        let c1 = a.matmul_bt(&b.transpose());
        let c2 = a.transpose().matmul_at(&b);
        assert!(c0.max_abs_diff(&c1) < 1e-12);
        assert!(c0.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn stack_and_slice_roundtrip() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let top = a.slice_rows(0, 2);
        let bot = a.slice_rows(2, 3);
        assert_eq!(Matrix::vstack(&[&top, &bot]), a);
        let l = a.slice_cols(0, 1);
        let r = a.slice_cols(1, 4);
        assert_eq!(Matrix::hstack(&[&l, &r]), a);
    }

    #[test]
    fn covariance_properties() {
        let x = Matrix::from_fn(4, 50, |i, j| ((i + 1) * j % 7) as f64 - 3.0);
        let c = x.covariance(1e-6);
        assert_eq!(c, c.symmetrize());
        // PSD: quadratic form nonneg for a few vectors
        for seed in 0..5u64 {
            let v: Vec<f64> = (0..4)
                .map(|i| ((seed as usize * 31 + i * 17) % 13) as f64 - 6.0)
                .collect();
            let cv = c.matvec(&v);
            let q: f64 = v.iter().zip(&cv).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-9);
        }
    }

    /// ikj-order reference with the same zero-skip as the kernels; any
    /// deviation in the parallel path shows up as a bit difference.
    fn matmul_reference(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for k in 0..a.cols() {
                let aik = a[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..b.cols() {
                    c[(i, j)] += aik * b[(k, j)];
                }
            }
        }
        c
    }

    #[test]
    fn parallel_matmul_is_bit_identical() {
        // 160³ ≈ 4M flops > PAR_MIN_FLOPS: exercises the row-parallel
        // path whenever the machine has >1 thread; the small case stays
        // serial. Both must match the reference exactly (not within eps).
        let mut rng = crate::util::rng::Rng::new(17);
        for n in [24usize, 160] {
            let a = rng.normal_matrix(n, n);
            let b = rng.normal_matrix(n, n);
            let c = a.matmul(&b);
            let r = matmul_reference(&a, &b);
            assert_eq!(c.data(), r.data(), "matmul n={n} diverged bitwise");

            let cbt = a.matmul_bt(&b.transpose());
            assert_eq!(cbt.data(), r.data(), "matmul_bt n={n}");

            let cat = a.transpose().matmul_at(&b);
            assert_eq!(cat.data(), r.data(), "matmul_at n={n}");
        }
    }

    #[test]
    fn single_row_matmul_bt_is_bit_identical() {
        // the matvec route for 1-row operands must reproduce the general
        // kernel's per-element arithmetic exactly (not within eps)
        let mut rng = crate::util::rng::Rng::new(41);
        let x = rng.normal_matrix(1, 96);
        let w = rng.normal_matrix(33, 96);
        let got = x.matmul_bt(&w);
        let mut want = Matrix::zeros(1, 33);
        for j in 0..33 {
            let mut s = 0.0;
            for k in 0..96 {
                s += x[(0, k)] * w[(j, k)];
            }
            want[(0, j)] = s;
        }
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn parallel_matmul_handles_ragged_row_blocks() {
        // rows not divisible by the block size: the final short chunk
        // must still land on the right rows
        let mut rng = crate::util::rng::Rng::new(23);
        let a = rng.normal_matrix(157, 160);
        let b = rng.normal_matrix(160, 163);
        assert_eq!(a.matmul(&b).data(), matmul_reference(&a, &b).data());
    }

    #[test]
    fn center_cols_zero_mean() {
        let x = Matrix::from_fn(3, 20, |i, j| (i * j) as f64 + 1.0);
        let mu = x.col_mean();
        let xc = x.center_cols(&mu);
        for m in xc.col_mean() {
            assert!(m.abs() < 1e-12);
        }
    }
}
