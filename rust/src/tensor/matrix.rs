//! Row-major f64 dense matrix with the handful of BLAS-3 style kernels the
//! compression algorithms need. The matmul family is cache-blocked and is
//! the §Perf hot path for the rust-side pipeline.

use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// C = A · B. ikj loop order (row-major streaming) — the fast path.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape {}x{} @ {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        let mut c = Matrix::zeros(self.rows, b.cols);
        let n = b.cols;
        for i in 0..self.rows {
            let crow = &mut c.data[i * n..(i + 1) * n];
            for k in 0..self.cols {
                let aik = self.data[i * self.cols + k];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[k * n..(k + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
        c
    }

    /// C = A · Bᵀ — dot-product form, both operands stream row-major.
    pub fn matmul_bt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_bt shape");
        let mut c = Matrix::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..b.rows {
                let brow = b.row(j);
                let mut s = 0.0;
                for k in 0..self.cols {
                    s += arow[k] * brow[k];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    /// C = Aᵀ · B.
    pub fn matmul_at(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_at shape");
        let mut c = Matrix::zeros(self.cols, b.cols);
        let n = b.cols;
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = &b.data[k * n..(k + 1) * n];
            for i in 0..self.cols {
                let aki = arow[i];
                if aki == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += aki * brow[j];
                }
            }
        }
        c
    }

    /// y = A · x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                row.iter().zip(x).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    pub fn add(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, b: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn add_inplace(&mut self, b: &Matrix) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (a, b) in self.data.iter_mut().zip(&b.data) {
            *a += b;
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let data = self.data.iter().map(|v| v * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale_inplace(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Squared Frobenius norm.
    pub fn frob2(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    /// Rows [r0, r1) as a new matrix.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Columns [c0, c1) as a new matrix.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        Matrix::from_fn(self.rows, c1 - c0, |i, j| self[(i, j + c0)])
    }

    /// Gather the given columns.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        Matrix::from_fn(self.rows, idx.len(), |i, j| self[(i, idx[j])])
    }

    /// Stack vertically.
    pub fn vstack(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            assert_eq!(b.cols, cols);
            data.extend_from_slice(&b.data);
        }
        Matrix { rows, cols, data }
    }

    /// Stack horizontally.
    pub fn hstack(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let rows = blocks[0].rows;
        let cols = blocks.iter().map(|b| b.cols).sum();
        let mut m = Matrix::zeros(rows, cols);
        let mut off = 0;
        for b in blocks {
            assert_eq!(b.rows, rows);
            for i in 0..rows {
                m.row_mut(i)[off..off + b.cols].copy_from_slice(b.row(i));
            }
            off += b.cols;
        }
        m
    }

    pub fn max_abs_diff(&self, b: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&b.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn symmetrize(&self) -> Matrix {
        assert_eq!(self.rows, self.cols);
        Matrix::from_fn(self.rows, self.cols,
                        |i, j| 0.5 * (self[(i, j)] + self[(j, i)]))
    }

    /// Column-token covariance C = (X Xᵀ + λ·tr/d·I)/l (paper Remark 3).
    pub fn covariance(&self, lam_rel: f64) -> Matrix {
        let l = self.cols.max(1) as f64;
        let mut c = self.matmul_bt(self);
        let tr = c.trace() / c.rows.max(1) as f64;
        let lam = lam_rel * tr.max(1e-12);
        for i in 0..c.rows {
            c[(i, i)] += lam;
        }
        c.scale_inplace(1.0 / l);
        c.symmetrize()
    }

    /// Column mean μ = X·1/l.
    pub fn col_mean(&self) -> Vec<f64> {
        let l = self.cols.max(1) as f64;
        (0..self.rows)
            .map(|i| self.row(i).iter().sum::<f64>() / l)
            .collect()
    }

    /// X − μ·1ᵀ.
    pub fn center_cols(&self, mu: &[f64]) -> Matrix {
        assert_eq!(mu.len(), self.rows);
        Matrix::from_fn(self.rows, self.cols, |i, j| self[(i, j)] - mu[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let b = Matrix::from_fn(4, 2, |i, j| (i + j) as f64 * 0.5);
        let c = a.matmul(&b);
        for i in 0..3 {
            for j in 0..2 {
                let mut s = 0.0;
                for k in 0..4 {
                    s += a[(i, k)] * b[(k, j)];
                }
                assert!((c[(i, j)] - s).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matmul_variants_agree() {
        let a = Matrix::from_fn(5, 7, |i, j| ((i * 13 + j * 7) % 11) as f64 - 5.0);
        let b = Matrix::from_fn(7, 4, |i, j| ((i * 5 + j * 3) % 9) as f64 - 4.0);
        let c0 = a.matmul(&b);
        let c1 = a.matmul_bt(&b.transpose());
        let c2 = a.transpose().matmul_at(&b);
        assert!(c0.max_abs_diff(&c1) < 1e-12);
        assert!(c0.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn stack_and_slice_roundtrip() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let top = a.slice_rows(0, 2);
        let bot = a.slice_rows(2, 3);
        assert_eq!(Matrix::vstack(&[&top, &bot]), a);
        let l = a.slice_cols(0, 1);
        let r = a.slice_cols(1, 4);
        assert_eq!(Matrix::hstack(&[&l, &r]), a);
    }

    #[test]
    fn covariance_properties() {
        let x = Matrix::from_fn(4, 50, |i, j| ((i + 1) * j % 7) as f64 - 3.0);
        let c = x.covariance(1e-6);
        assert_eq!(c, c.symmetrize());
        // PSD: quadratic form nonneg for a few vectors
        for seed in 0..5u64 {
            let v: Vec<f64> = (0..4)
                .map(|i| ((seed as usize * 31 + i * 17) % 13) as f64 - 6.0)
                .collect();
            let cv = c.matvec(&v);
            let q: f64 = v.iter().zip(&cv).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-9);
        }
    }

    #[test]
    fn center_cols_zero_mean() {
        let x = Matrix::from_fn(3, 20, |i, j| (i * j) as f64 + 1.0);
        let mu = x.col_mean();
        let xc = x.center_cols(&mu);
        for m in xc.col_mean() {
            assert!(m.abs() < 1e-12);
        }
    }
}
