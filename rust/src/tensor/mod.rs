//! Dense linear-algebra substrate (no BLAS/LAPACK in this environment —
//! built from scratch, property-tested; see DESIGN.md §2).

pub mod eig;
pub mod linalg;
pub mod matrix;
pub mod packed;
pub mod svd;

pub use eig::{eigh, topk_eigvecs};
pub use linalg::{cholesky, invsqrtm_psd, pinv, pinv_psd, solve,
                 sqrt_and_invsqrt_psd, sqrtm_psd};
pub use matrix::Matrix;
pub use packed::{Layout, PackedMat};
pub use svd::{svd, svd_truncated, Svd};
