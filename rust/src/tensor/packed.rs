//! Typed execution layouts (ROADMAP: "compression only pays off when the
//! compressed layout is also the *execution* layout"). A [`PackedMat`] is
//! a weight matrix stored in the form the kernel that consumes it wants:
//!
//! * [`PackedMat::DenseF64`] — the historical layout. Dispatch delegates
//!   to [`Matrix::matmul_bt`], so every result is bit-identical to the
//!   pre-layout code (pinned by tests/layouts.rs).
//! * `PackedF32` — a column-panel pack of the transposed weight operand:
//!   [`NR`] output rows interleaved k-major, so the matvec-shaped decode
//!   step (`x` is one row) streams the panel once and keeps [`NR`]
//!   independent accumulators live — legal ILP/SIMD without reassociating
//!   any single dot product.
//! * `QuantI8` — chunk-wise affine int8 on the same flat-buffer grid as
//!   `compress/quant.rs::quantize_uniform` (paper Eq 242): per-chunk
//!   `scale`/`zero_point`, i8 weight reads, dequant fused into the dot
//!   epilogue via `y = Σ_c scale_c·(x·q)_c + zp_c·Σ x_c`.
//!
//! Activations stay f64 throughout — the quantized path loses precision
//! only through the weight grid itself, which is what lets the property
//! test (`QuantI8` matmul == dequantize-then-f64-matmul) hold to ~1e-13.

use anyhow::{bail, Result};

use super::matrix::{par_plan, Matrix};

/// Output-panel width of the `PackedF32` pack (accumulators per panel).
pub const NR: usize = 8;

/// Chunk width the degenerate guard shares with `quantize_uniform`.
pub const DEGENERATE_EPS: f64 = 1e-12;

/// Execution layout of a weight set — persisted in the LTW2 artifact tag
/// and selected at the CLI with `compress --layout`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    DenseF64,
    PackedF32,
    QuantI8,
}

impl Layout {
    /// Stable on-disk code (LTW2 layout byte).
    pub fn code(self) -> u8 {
        match self {
            Layout::DenseF64 => 0,
            Layout::PackedF32 => 1,
            Layout::QuantI8 => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<Layout> {
        Ok(match c {
            0 => Layout::DenseF64,
            1 => Layout::PackedF32,
            2 => Layout::QuantI8,
            _ => bail!("unknown layout code {c}"),
        })
    }

    /// CLI spelling (`compress --layout f64|f32|int8`).
    pub fn parse(s: &str) -> Result<Layout> {
        Ok(match s {
            "f64" | "dense" => Layout::DenseF64,
            "f32" | "packed" => Layout::PackedF32,
            "int8" | "i8" => Layout::QuantI8,
            _ => bail!("unknown layout {s:?} (expected f64, f32 or int8)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Layout::DenseF64 => "f64",
            Layout::PackedF32 => "f32",
            Layout::QuantI8 => "int8",
        }
    }
}

/// A weight matrix in its execution layout. Logical shape is always
/// `[rows, cols]` in the paper's `W[out, in]` convention; [`PackedMat::apply`]
/// computes `x · Wᵀ` with the layout's kernel.
#[derive(Clone, Debug, PartialEq)]
pub enum PackedMat {
    DenseF64(Matrix),
    PackedF32 {
        rows: usize,
        cols: usize,
        /// `rows.div_ceil(NR)` panels, each `cols × NR` k-major: element
        /// `(p, k, r)` holds `W[p·NR + r, k]` (zero-padded tail panel).
        data: Vec<f32>,
    },
    QuantI8 {
        rows: usize,
        cols: usize,
        /// Row-major i8 codes; flat index `i` belongs to chunk `i / chunk`.
        data: Vec<i8>,
        /// Per-chunk step `(hi - lo) / 255` (0.0 for a constant chunk).
        scales: Vec<f32>,
        /// Per-chunk affine offset `lo + 128·step`: `ŵ = q·scale + zp`.
        zero_points: Vec<f32>,
        chunk: usize,
    },
}

impl PackedMat {
    pub fn layout(&self) -> Layout {
        match self {
            PackedMat::DenseF64(_) => Layout::DenseF64,
            PackedMat::PackedF32 { .. } => Layout::PackedF32,
            PackedMat::QuantI8 { .. } => Layout::QuantI8,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            PackedMat::DenseF64(m) => m.rows(),
            PackedMat::PackedF32 { rows, .. }
            | PackedMat::QuantI8 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedMat::DenseF64(m) => m.cols(),
            PackedMat::PackedF32 { cols, .. }
            | PackedMat::QuantI8 { cols, .. } => *cols,
        }
    }

    /// Weight-payload bytes in this layout (the bandwidth the kernel pays).
    pub fn payload_bytes(&self) -> usize {
        match self {
            PackedMat::DenseF64(m) => m.rows() * m.cols() * 8,
            PackedMat::PackedF32 { data, .. } => data.len() * 4,
            PackedMat::QuantI8 { data, scales, zero_points, .. } => {
                data.len() + (scales.len() + zero_points.len()) * 4
            }
        }
    }

    pub fn dense(m: Matrix) -> PackedMat {
        PackedMat::DenseF64(m)
    }

    /// Pack into NR-wide column panels of the transposed operand.
    pub fn pack_f32(m: &Matrix) -> PackedMat {
        let (rows, cols) = (m.rows(), m.cols());
        let panels = rows.div_ceil(NR);
        let mut data = vec![0.0f32; panels * cols * NR];
        for p in 0..panels {
            let panel = &mut data[p * cols * NR..(p + 1) * cols * NR];
            for k in 0..cols {
                for r in 0..NR {
                    let i = p * NR + r;
                    if i < rows {
                        panel[k * NR + r] = m[(i, k)] as f32;
                    }
                }
            }
        }
        PackedMat::PackedF32 { rows, cols, data }
    }

    /// Chunk-wise affine int8 on the `quantize_uniform` flat-buffer grid.
    /// A degenerate chunk (`hi - lo <= 1e-12`) stores `scale = 0`,
    /// `zero_point = lo`, codes 0 — constant chunks round-trip exactly.
    pub fn quantize_i8(m: &Matrix, chunk: usize) -> PackedMat {
        assert!(chunk >= 1, "quantize_i8 needs chunk >= 1");
        let src = m.data();
        let n = src.len();
        let n_chunks = n.div_ceil(chunk);
        let mut data = vec![0i8; n];
        let mut scales = vec![0.0f32; n_chunks];
        let mut zero_points = vec![0.0f32; n_chunks];
        let mut s = 0;
        let mut c = 0;
        while s < n {
            let e = (s + chunk).min(n);
            let seg = &src[s..e];
            let lo = seg.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = seg.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if hi - lo > DEGENERATE_EPS {
                // same grid as quantize_uniform: q_u = round((v-lo)·255/(hi-lo))
                let scale = 255.0 / (hi - lo);
                let step = (hi - lo) / 255.0;
                for (d, &v) in data[s..e].iter_mut().zip(seg) {
                    let q = (((v - lo) * scale).round() as i32 - 128)
                        .clamp(-128, 127);
                    *d = q as i8;
                }
                scales[c] = step as f32;
                zero_points[c] = (lo + 128.0 * step) as f32;
            } else {
                scales[c] = 0.0;
                zero_points[c] = lo as f32;
            }
            s = e;
            c += 1;
        }
        PackedMat::QuantI8 { rows: m.rows(), cols: m.cols(), data, scales,
                             zero_points, chunk }
    }

    /// Pack a dense matrix into the given layout.
    pub fn pack(m: Matrix, layout: Layout, chunk: usize) -> PackedMat {
        match layout {
            Layout::DenseF64 => PackedMat::DenseF64(m),
            Layout::PackedF32 => PackedMat::pack_f32(&m),
            Layout::QuantI8 => PackedMat::quantize_i8(&m, chunk),
        }
    }

    /// Densify back to f64 — the dequantized reference the property test
    /// compares the fused kernels against (and the view `compress/`,
    /// `eval/` and reports keep using on non-dense artifacts).
    pub fn to_matrix(&self) -> Matrix {
        match self {
            PackedMat::DenseF64(m) => m.clone(),
            PackedMat::PackedF32 { rows, cols, data } => {
                Matrix::from_fn(*rows, *cols, |i, k| {
                    let p = i / NR;
                    data[p * cols * NR + k * NR + (i % NR)] as f64
                })
            }
            PackedMat::QuantI8 { rows, cols, data, scales, zero_points,
                                 chunk } => {
                let mut m = Matrix::zeros(*rows, *cols);
                for (idx, v) in m.data_mut().iter_mut().enumerate() {
                    let c = idx / chunk;
                    *v = data[idx] as f64 * scales[c] as f64
                        + zero_points[c] as f64;
                }
                m
            }
        }
    }

    /// `x · Wᵀ` with the layout's kernel. The `DenseF64` arm IS
    /// [`Matrix::matmul_bt`] — bit-identical to the pre-layout code; the
    /// packed arms trade bit-identity for bandwidth and ILP.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.cols(), "apply shape {}x{} · ({}x{})ᵀ",
                   x.rows(), x.cols(), self.rows(), self.cols());
        match self {
            PackedMat::DenseF64(w) => x.matmul_bt(w),
            PackedMat::PackedF32 { rows, cols, data } => {
                apply_packed_f32(x, *rows, *cols, data)
            }
            PackedMat::QuantI8 { rows, cols, data, scales, zero_points,
                                 chunk } => {
                apply_quant_i8(x, *rows, *cols, data, scales, zero_points,
                               *chunk)
            }
        }
    }
}

fn apply_packed_f32(x: &Matrix, rows: usize, cols: usize, data: &[f32])
                    -> Matrix {
    let t = x.rows();
    let mut c = Matrix::zeros(t, rows);
    let flops = t * cols * rows;
    if let Some((pool, block)) = par_plan(t, rows, flops) {
        pool.par_chunks(c.data_mut(), block * rows, |bi, chunk| {
            for (di, crow) in chunk.chunks_mut(rows).enumerate() {
                packed_f32_row(x.row(bi * block + di), crow, rows, cols,
                               data);
            }
        });
    } else {
        for i in 0..t {
            let xr = x.row(i);
            let crow = &mut c.data_mut()[i * rows..(i + 1) * rows];
            packed_f32_row(xr, crow, rows, cols, data);
        }
    }
    c
}

/// One activation row against every NR-panel: NR independent f64
/// accumulators per panel, panel streamed k-major exactly once.
fn packed_f32_row(xr: &[f64], crow: &mut [f64], rows: usize, cols: usize,
                  data: &[f32]) {
    let panels = rows.div_ceil(NR);
    for p in 0..panels {
        let panel = &data[p * cols * NR..(p + 1) * cols * NR];
        let mut acc = [0.0f64; NR];
        for (k, &xv) in xr.iter().enumerate() {
            let wk = &panel[k * NR..k * NR + NR];
            for r in 0..NR {
                acc[r] += xv * wk[r] as f64;
            }
        }
        let base = p * NR;
        let m = NR.min(rows - base);
        crow[base..base + m].copy_from_slice(&acc[..m]);
    }
}

fn apply_quant_i8(x: &Matrix, rows: usize, cols: usize, data: &[i8],
                  scales: &[f32], zero_points: &[f32], chunk: usize)
                  -> Matrix {
    let t = x.rows();
    let mut c = Matrix::zeros(t, rows);
    if rows == 0 || cols == 0 {
        return c;
    }
    let flops = t * cols * rows;
    if let Some((pool, block)) = par_plan(t, rows, flops) {
        pool.par_chunks(c.data_mut(), block * rows, |bi, chunk_out| {
            for (di, crow) in chunk_out.chunks_mut(rows).enumerate() {
                quant_i8_row(x.row(bi * block + di), crow, rows, cols, data,
                             scales, zero_points, chunk);
            }
        });
    } else {
        for i in 0..t {
            let xr = x.row(i);
            let crow = &mut c.data_mut()[i * rows..(i + 1) * rows];
            quant_i8_row(xr, crow, rows, cols, data, scales, zero_points,
                         chunk);
        }
    }
    c
}

/// One activation row against every quantized weight row. Chunks live on
/// the *flat* weight buffer (they may span row boundaries), so weight row
/// `j` starts `(j·cols) % chunk` elements into its first chunk; the
/// per-offset activation segment sums are computed once per activation
/// row and shared by every weight row with the same phase.
#[allow(clippy::too_many_arguments)]
fn quant_i8_row(xr: &[f64], crow: &mut [f64], rows: usize, cols: usize,
                data: &[i8], scales: &[f32], zero_points: &[f32],
                chunk: usize) {
    let mut seg_cache: Vec<Option<Vec<f64>>> = vec![None; chunk];
    for (j, out) in crow.iter_mut().enumerate().take(rows) {
        let qrow = &data[j * cols..(j + 1) * cols];
        let flat0 = j * cols;
        let off = flat0 % chunk;
        let sums = seg_cache[off]
            .get_or_insert_with(|| seg_sums(xr, chunk, off));
        let mut cidx = flat0 / chunk;
        let mut k = 0usize;
        let mut si = 0usize;
        let mut acc = 0.0f64;
        let mut e = (chunk - off).min(cols);
        loop {
            let dot = dot_qi8(&xr[k..e], &qrow[k..e]);
            acc += scales[cidx] as f64 * dot
                + zero_points[cidx] as f64 * sums[si];
            if e == cols {
                break;
            }
            k = e;
            e = (e + chunk).min(cols);
            cidx += 1;
            si += 1;
        }
        *out = acc;
    }
}

/// Activation segment sums on the flat-chunk grid at phase `off`.
fn seg_sums(x: &[f64], chunk: usize, off: usize) -> Vec<f64> {
    let n = x.len();
    let mut out = Vec::with_capacity(n / chunk + 2);
    let mut k = 0usize;
    let mut e = (chunk - off).min(n);
    loop {
        out.push(x[k..e].iter().sum());
        if e == n {
            break;
        }
        k = e;
        e = (e + chunk).min(n);
    }
    out
}

/// f64 · i8 dot with four independent accumulation chains — the packed
/// paths have no bit-identity pin, so breaking the serial FP dependency
/// chain is legal here (unlike `Matrix::matmul_bt`'s strict-order dots).
#[inline]
fn dot_qi8(x: &[f64], q: &[i8]) -> f64 {
    let n = x.len().min(q.len());
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut k = 0usize;
    while k + 4 <= n {
        a0 += x[k] * q[k] as f64;
        a1 += x[k + 1] * q[k + 1] as f64;
        a2 += x[k + 2] * q[k + 2] as f64;
        a3 += x[k + 3] * q[k + 3] as f64;
        k += 4;
    }
    let mut s = (a0 + a1) + (a2 + a3);
    while k < n {
        s += x[k] * q[k] as f64;
        k += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_dispatch_is_bit_identical() {
        let mut rng = Rng::new(7);
        let x = rng.normal_matrix(5, 24);
        let w = rng.normal_matrix(13, 24);
        let p = PackedMat::dense(w.clone());
        assert_eq!(p.apply(&x).data(), x.matmul_bt(&w).data());
        assert_eq!(p.to_matrix(), w);
    }

    #[test]
    fn packed_f32_matches_reference_within_f32_noise() {
        let mut rng = Rng::new(8);
        for (t, out, k) in [(1, 13, 24), (4, 8, 7), (3, 1, 1), (2, 9, 33)] {
            let x = rng.normal_matrix(t, k);
            let w = rng.normal_matrix(out, k);
            let p = PackedMat::pack_f32(&w);
            assert_eq!((p.rows(), p.cols()), (out, k));
            // reference on the *f32-rounded* weights: the pack loses only
            // the f64→f32 cast, never an element
            let got = p.apply(&x);
            let want = x.matmul_bt(&p.to_matrix());
            assert!(got.max_abs_diff(&want) < 1e-9,
                    "t={t} out={out} k={k}");
        }
    }

    #[test]
    fn quant_i8_roundtrip_error_bounded_by_half_step() {
        let mut rng = Rng::new(9);
        let w = rng.normal_matrix(6, 10);
        let p = PackedMat::quantize_i8(&w, 16);
        let back = p.to_matrix();
        let PackedMat::QuantI8 { ref scales, .. } = p else { unreachable!() };
        for idx in 0..60 {
            let (i, j) = (idx / 10, idx % 10);
            let step = scales[idx / 16] as f64;
            // half-step quantization error + f32 param rounding
            let tol = 0.5 * step + 1e-6 * (1.0 + w[(i, j)].abs());
            assert!((back[(i, j)] - w[(i, j)]).abs() <= tol,
                    "({i},{j}): {} vs {}", back[(i, j)], w[(i, j)]);
        }
    }

    #[test]
    fn quant_i8_constant_chunk_is_exact() {
        // all-equal matrix: every chunk degenerate → exact representation
        let w = Matrix::from_fn(3, 5, |_, _| 0.37);
        let p = PackedMat::quantize_i8(&w, 4);
        assert_eq!(p.to_matrix().max_abs_diff(&w), (0.37f32 as f64 - 0.37).abs());
        // scale must be 0 (not garbage) so the kernel stays finite
        let PackedMat::QuantI8 { scales, .. } = &p else { unreachable!() };
        assert!(scales.iter().all(|&s| s == 0.0));
        // single-element tail chunk (15 elements, chunk 4 → last chunk 3;
        // chunk 7 → tail of 1)
        let w1 = Matrix::from_fn(1, 15, |_, j| j as f64);
        let p1 = PackedMat::quantize_i8(&w1, 7);
        let b1 = p1.to_matrix();
        assert!((b1[(0, 14)] - 14.0).abs() < 1e-6, "single-element chunk");
    }

    #[test]
    fn quant_i8_apply_matches_dequant_reference() {
        let mut rng = Rng::new(10);
        for (t, out, k, chunk) in
            [(1, 9, 24, 8), (3, 5, 10, 7), (2, 4, 6, 64), (1, 1, 1, 1)] {
            let x = rng.normal_matrix(t, k);
            let w = rng.normal_matrix(out, k);
            let p = PackedMat::quantize_i8(&w, chunk);
            let got = p.apply(&x);
            let want = x.matmul_bt(&p.to_matrix());
            let denom = 1.0 + want.data().iter().cloned().map(f64::abs)
                .fold(0.0, f64::max);
            assert!(got.max_abs_diff(&want) / denom < 1e-12,
                    "t={t} out={out} k={k} chunk={chunk}");
        }
    }

    #[test]
    fn layout_codes_roundtrip() {
        for l in [Layout::DenseF64, Layout::PackedF32, Layout::QuantI8] {
            assert_eq!(Layout::from_code(l.code()).unwrap(), l);
            assert_eq!(Layout::parse(l.name()).unwrap(), l);
        }
        assert!(Layout::from_code(9).is_err());
        assert!(Layout::parse("fp4").is_err());
    }
}
