//! Singular value decomposition via one-sided Jacobi.
//!
//! One-sided Jacobi orthogonalizes the columns of A by plane rotations;
//! at convergence the column norms are the singular values, the normalized
//! columns form U, and the accumulated rotations form V. It is simple,
//! backward-stable, and accurate for small singular values — the property
//! that matters when truncating (paper Eq 6) because the tail energy *is*
//! the compression loss.

use super::matrix::Matrix;

pub struct Svd {
    /// d'×r left singular vectors (orthonormal columns).
    pub u: Matrix,
    /// r singular values, descending.
    pub s: Vec<f64>,
    /// r×d right singular vectors as rows (orthonormal rows).
    pub vt: Matrix,
}

/// Full (thin) SVD: a = U diag(s) Vt with r = min(rows, cols).
pub fn svd(a: &Matrix) -> Svd {
    if a.rows() >= a.cols() {
        svd_tall(a)
    } else {
        // A = U S Vt  ⇔  Aᵀ = V S Uᵀ
        let t = svd_tall(&a.transpose());
        Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() }
    }
}

fn svd_tall(a: &Matrix) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    debug_assert!(m >= n);
    let mut u = a.clone(); // working copy; columns get orthogonalized
    let mut v = Matrix::eye(n);
    let eps = 1e-15;
    let max_sweeps = 60;

    for _ in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                rotated = true;
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum()
                    / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // Column norms = singular values; sort descending.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let s: f64 = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum();
            (s.sqrt(), j)
        })
        .collect();
    // total_cmp: a non-finite value from a degenerate Gram matrix must
    // sort deterministically, not panic. (+NaN orders above +inf, so a
    // NaN norm sorts *first* here — visible to callers via the finite-
    // weights checks rather than a crashed pipeline thread.)
    sv.sort_by(|a, b| b.0.total_cmp(&a.0));
    let idx: Vec<usize> = sv.iter().map(|&(_, j)| j).collect();
    let s: Vec<f64> = sv.iter().map(|&(v, _)| v).collect();
    let mut u_sorted = u.select_cols(&idx);
    let v_sorted = v.select_cols(&idx);
    for (j, &sj) in s.iter().enumerate() {
        if sj > 1e-300 {
            for i in 0..m {
                u_sorted[(i, j)] /= sj;
            }
        }
    }
    Svd { u: u_sorted, s, vt: v_sorted.transpose() }
}

/// Rank-r truncated SVD (paper Eq 6: U S V = svd_r[W P]).
///
/// §Perf: computed via the Gram-matrix eigendecomposition of the smaller
/// side (eigh(AᵀA) or eigh(AAᵀ)) — O(mn·min(m,n) + min(m,n)³) with a much
/// smaller constant than one-sided Jacobi on the full matrix. Relative
/// accuracy of the kept singular triplets is ~√ε·κ, ample for truncation
/// (the discarded tail *is* the compression loss). `svd()` remains the
/// full-accuracy Jacobi path.
pub fn svd_truncated(a: &Matrix, r: usize) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let k = m.min(n);
    let r = r.min(k);
    if k <= 8 {
        // tiny problems: Jacobi is already fast and exact
        let full = svd(a);
        return Svd {
            u: full.u.slice_cols(0, r),
            s: full.s[..r].to_vec(),
            vt: full.vt.slice_rows(0, r),
        };
    }
    use super::eig::eigh;
    if n <= m {
        // AᵀA = V S² Vᵀ;  U = A V S⁻¹
        let gram = a.matmul_at(a).symmetrize();
        let (w, v) = eigh(&gram);
        // eigenvalues ascend: take top r
        let idx: Vec<usize> = (0..r).map(|i| n - 1 - i).collect();
        let vsel = v.select_cols(&idx); // n×r
        let s: Vec<f64> = idx.iter().map(|&i| w[i].max(0.0).sqrt()).collect();
        let mut u = a.matmul(&vsel); // m×r
        for j in 0..r {
            let inv = if s[j] > 1e-300 { 1.0 / s[j] } else { 0.0 };
            for i in 0..m {
                u[(i, j)] *= inv;
            }
        }
        Svd { u, s, vt: vsel.transpose() }
    } else {
        // A Aᵀ = U S² Uᵀ;  Vᵀ = S⁻¹ Uᵀ A
        let gram = a.matmul_bt(a).symmetrize();
        let (w, u_full) = eigh(&gram);
        let idx: Vec<usize> = (0..r).map(|i| m - 1 - i).collect();
        let usel = u_full.select_cols(&idx); // m×r
        let s: Vec<f64> = idx.iter().map(|&i| w[i].max(0.0).sqrt()).collect();
        let mut vt = usel.matmul_at(a); // uselᵀ·a = r×n
        for i in 0..r {
            let inv = if s[i] > 1e-300 { 1.0 / s[i] } else { 0.0 };
            for v in vt.row_mut(i) {
                *v *= inv;
            }
        }
        Svd { u: usel, s, vt }
    }
}

impl Svd {
    /// U diag(s) Vt.
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        for j in 0..self.s.len() {
            for i in 0..us.rows() {
                us[(i, j)] *= self.s[j];
            }
        }
        us.matmul(&self.vt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn svd_reconstructs_all_shapes() {
        let mut rng = Rng::new(2);
        for (m, n) in [(1, 1), (4, 4), (7, 3), (3, 7), (20, 12), (12, 20)] {
            let a = rng.normal_matrix(m, n);
            let f = svd(&a);
            assert!(f.reconstruct().max_abs_diff(&a) < 1e-9,
                    "shape {m}x{n}");
            let utu = f.u.matmul_at(&f.u);
            assert!(utu.max_abs_diff(&Matrix::eye(f.s.len())) < 1e-9);
            let vvt = f.vt.matmul_bt(&f.vt);
            assert!(vvt.max_abs_diff(&Matrix::eye(f.s.len())) < 1e-9);
            for i in 1..f.s.len() {
                assert!(f.s[i] <= f.s[i - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn truncation_error_is_tail_energy() {
        // Eckart–Young: ‖A − A_r‖²_F = Σ_{i>r} σᵢ².
        let mut rng = Rng::new(3);
        let a = rng.normal_matrix(10, 8);
        let full = svd(&a);
        for r in [1usize, 3, 5, 8] {
            let t = svd_truncated(&a, r);
            let err = a.sub(&t.reconstruct()).frob2();
            let tail: f64 = full.s[r.min(8)..].iter().map(|s| s * s).sum();
            assert!((err - tail).abs() < 1e-8 * (1.0 + tail),
                    "r={r}: {err} vs {tail}");
        }
    }

    #[test]
    fn svd_rank_deficient() {
        // rank-2 matrix of size 6x5
        let mut rng = Rng::new(4);
        let b = rng.normal_matrix(6, 2);
        let c = rng.normal_matrix(2, 5);
        let a = b.matmul(&c);
        let f = svd(&a);
        assert!(f.s[2] < 1e-9 * f.s[0].max(1.0));
        let t = svd_truncated(&a, 2);
        assert!(t.reconstruct().max_abs_diff(&a) < 1e-8);
    }
}
