//! Micro-benchmark substrate (criterion is unavailable offline).
//!
//! `Bench::run` measures a closure with warmup, adaptive iteration count,
//! and reports min/mean/p50/p95 wall time. All `cargo bench` targets
//! (harness = false) are built on this.

use std::time::Instant;

pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl Stats {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  min {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    /// Target total measurement time per case (seconds).
    pub budget_s: f64,
    /// Hard cap on iterations.
    pub max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { budget_s: 1.0, max_iters: 1000, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(budget_s: f64) -> Self {
        Bench { budget_s, ..Default::default() }
    }

    /// Measure `f`, printing the stats line immediately.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // warmup + calibration
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let cap = self.max_iters.max(1);
        let iters = ((self.budget_s / once) as usize)
            .clamp(3.min(cap), cap);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let stats = Stats {
            name: name.to_string(),
            iters,
            mean_ns: samples.iter().sum::<f64>() / iters as f64,
            min_ns: samples[0],
            p50_ns: samples[iters / 2],
            p95_ns: samples[(iters * 95) / 100..].first().copied()
                .unwrap_or(samples[iters - 1]),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new(0.01);
        let s = b.run("noop-ish", || {
            let mut x = 0u64;
            for i in 0..100u64 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns * 1.5);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5.0e4).ends_with("µs"));
        assert!(fmt_ns(5.0e7).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with("s"));
    }
}
