//! Minimal JSON substrate (serde is unavailable offline): a `Value` tree,
//! a recursive-descent parser, and a writer. Handles everything the
//! artifacts pipeline emits (manifest.json, goldens.json) plus the report
//! outputs this crate writes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `v.path(&["models", "opt-mini-m", "base_ppl"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, 0, true);
        s
    }

    /// Single-line rendering — what the HTTP layer emits (streaming
    /// events are newline-framed, so bodies must not contain newlines).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, 0, false);
        s
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<Vec<f64>> for Value {
    fn from(v: Vec<f64>) -> Self {
        Value::Arr(v.into_iter().map(Value::Num).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{}", n);
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_value(out: &mut String, v: &Value, indent: usize, pretty: bool) {
    let pad = if pretty { "  ".repeat(indent + 1) } else { String::new() };
    let padc = if pretty { "  ".repeat(indent) } else { String::new() };
    let nl = if pretty { "\n" } else { "" };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_escaped(out, s),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(nl);
                out.push_str(&pad);
                write_value(out, item, indent + 1, pretty);
            }
            out.push_str(nl);
            out.push_str(&padc);
            out.push(']');
        }
        Value::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(nl);
                out.push_str(&pad);
                write_escaped(out, k);
                out.push_str(": ");
                write_value(out, item, indent + 1, pretty);
            }
            out.push_str(nl);
            out.push_str(&padc);
            out.push('}');
        }
    }
}

pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected {:?} at {}", c as char, pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Value::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Value::Str(s) => s,
                    _ => bail!("object key must be string"),
                };
                expect(b, pos, b':')?;
                let v = parse_value(b, pos)?;
                m.insert(key, v);
                skip_ws(b, pos);
                if *pos >= b.len() {
                    bail!("unterminated object");
                }
                match b[*pos] {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        return Ok(Value::Obj(m));
                    }
                    c => bail!("bad object separator {:?}", c as char),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Value::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                if *pos >= b.len() {
                    bail!("unterminated array");
                }
                match b[*pos] {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        return Ok(Value::Arr(a));
                    }
                    c => bail!("bad array separator {:?}", c as char),
                }
            }
        }
        b'"' => parse_string(b, pos).map(Value::Str),
        b't' => {
            literal(b, pos, "true")?;
            Ok(Value::Bool(true))
        }
        b'f' => {
            literal(b, pos, "false")?;
            Ok(Value::Bool(false))
        }
        b'n' => {
            literal(b, pos, "null")?;
            Ok(Value::Null)
        }
        _ => parse_number(b, pos),
    }
}

fn literal(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("bad literal at {pos}")
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("bad escape");
                }
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("bad \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        s.push(char::from_u32(code)
                            .ok_or_else(|| anyhow!("bad codepoint"))?);
                        *pos += 4;
                    }
                    c => bail!("unknown escape {:?}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy a UTF-8 run
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                s.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
    bail!("unterminated string")
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos],
                    b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = text.parse().map_err(|_| anyhow!("bad number {text:?}"))?;
    Ok(Value::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = Value::obj(vec![
            ("name", "latentllm".into()),
            ("ratio", 0.3.into()),
            ("flags", Value::Arr(vec![true.into(), Value::Null, 42.0.into()])),
            ("nested", Value::obj(vec![("k", "v\n\"q\"".into())])),
        ]);
        let text = v.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
        let compact = v.to_string_compact();
        assert!(!compact.contains('\n'), "compact output is one line");
        assert_eq!(parse(&compact).unwrap(), v);
    }

    #[test]
    fn parses_scientific_and_negative() {
        let v = parse("[-1.5e-3, 2E4, 0.0, -7]").unwrap();
        let a = v.as_arr().unwrap();
        assert!((a[0].as_f64().unwrap() + 0.0015).abs() < 1e-12);
        assert_eq!(a[1].as_f64().unwrap(), 20000.0);
        assert_eq!(a[3].as_f64().unwrap(), -7.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,,2]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\":1} trailing").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse("\"\\u00e9\\n\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é\n");
    }
}
