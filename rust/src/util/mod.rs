//! Substrate utilities built in-repo (the environment is offline, so the
//! usual crates — serde, rand, proptest, criterion — are replaced by these
//! small, tested implementations; see DESIGN.md §2).

pub mod bench;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod toml;

/// Lock a mutex, recovering from poisoning instead of propagating the
/// panic. For pure memo caches (the engine's program cache, the ref
/// backend's model cache, shared queues of owned values) every reachable
/// state is valid — the poison flag only records that *some* thread
/// panicked while holding the guard, and un-poisoning costs at worst a
/// recomputed cache entry. Without this, one worker's panic turns every
/// sibling's `.lock().unwrap()` into a cascade that kills the whole
/// server.
pub fn lock_unpoisoned<T: ?Sized>(m: &std::sync::Mutex<T>)
                                  -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use std::sync::{Arc, Mutex};

    use super::lock_unpoisoned;

    #[test]
    fn lock_unpoisoned_recovers_after_a_holder_panics() {
        let m = Arc::new(Mutex::new(41));
        let m2 = m.clone();
        // poison: panic while holding the guard on another thread
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "the mutex must actually be poisoned");
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 41, "state survives — the panic left it valid");
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 42);
    }
}
