//! Substrate utilities built in-repo (the environment is offline, so the
//! usual crates — serde, rand, proptest, criterion — are replaced by these
//! small, tested implementations; see DESIGN.md §2).

pub mod bench;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod toml;
