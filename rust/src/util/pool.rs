//! Scoped thread pool for the compression and serving hot paths.
//!
//! std-only (rayon is unavailable offline): every parallel operation is a
//! fork-join over `std::thread::scope`, so no worker threads outlive a
//! call and closures may borrow from the caller's stack freely. Sizing
//! comes from `LATENTLLM_THREADS` when set, else
//! `std::thread::available_parallelism`.
//!
//! Determinism contract: `run` returns results in job order and
//! `par_chunks` hands each closure a disjoint chunk, so callers that keep
//! per-job arithmetic identical to their serial path (the `tensor` matmul
//! family and `compress::pipeline` do) produce bit-identical output at any
//! thread count.
//!
//! Nesting: closures executing on a pool worker are flagged thread-local;
//! nested pool calls from inside a worker degrade to the serial path
//! instead of oversubscribing the machine quadratically (layer-parallel
//! `compress_model` on top of row-parallel `matmul` is the motivating
//! stack).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Process-wide thread-count override; 0 = not yet resolved.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Thread count from the environment: `LATENTLLM_THREADS` when it parses
/// to a positive integer, else `available_parallelism`, else 1.
pub fn configured_threads() -> usize {
    if let Ok(v) = std::env::var("LATENTLLM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(256);
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Override the global pool size (benches and tests; takes effect for all
/// subsequent [`Pool::global`] calls in this process).
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// A fork-join executor of fixed width. `Pool` is a value type (one
/// `usize`): construction never spawns threads, each parallel call does.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// The process-wide pool: sized by [`configured_threads`] on first
    /// use, overridable with [`set_global_threads`].
    pub fn global() -> Pool {
        let mut n = GLOBAL_THREADS.load(Ordering::Relaxed);
        if n == 0 {
            n = configured_threads();
            GLOBAL_THREADS.store(n, Ordering::Relaxed);
        }
        Pool::new(n)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when called from a closure already running on a pool worker
    /// (nested parallel calls run serially).
    pub fn in_worker() -> bool {
        IN_WORKER.with(|f| f.get())
    }

    /// Mark the *current* thread as a pool-style worker: every pool call
    /// made from it runs serially. Long-lived compute threads that exist
    /// in multiples (the serving workers) use this so N of them don't
    /// each fan out a full pool on top of each other.
    pub fn mark_worker_thread() {
        IN_WORKER.with(|f| f.set(true));
    }

    /// Run `f(0), f(1), …, f(jobs-1)` across the pool and return the
    /// results **in job order**. Jobs are claimed dynamically (atomic
    /// counter), so imbalanced jobs still fill all workers.
    pub fn run<T, F>(&self, jobs: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = self.threads.min(jobs);
        if workers <= 1 || Pool::in_worker() {
            return (0..jobs).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let f = &f;
                s.spawn(move || {
                    IN_WORKER.with(|flag| flag.set(true));
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs {
                            break;
                        }
                        if tx.send((i, f(i))).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<T>> = (0..jobs).map(|_| None).collect();
            for (i, v) in rx {
                out[i] = Some(v);
            }
            out.into_iter()
                .map(|v| v.expect("pool worker completed every job"))
                .collect()
        })
    }

    /// Split `data` into consecutive chunks of `chunk_len` elements (the
    /// last may be shorter) and run `f(chunk_index, chunk)` across the
    /// pool. Chunks are disjoint, so writes race-free by construction.
    pub fn par_chunks<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk_len > 0, "par_chunks needs chunk_len >= 1");
        let n_chunks = data.len().div_ceil(chunk_len);
        let workers = self.threads.min(n_chunks);
        if workers <= 1 || Pool::in_worker() {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(i, c);
            }
            return;
        }
        std::thread::scope(|s| {
            // static round-robin: uniform chunks (the matmul row blocks)
            // balance without a shared queue
            let mut buckets: Vec<Vec<(usize, &mut [T])>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                buckets[i % workers].push((i, c));
            }
            for bucket in buckets {
                let f = &f;
                s.spawn(move || {
                    IN_WORKER.with(|flag| flag.set(true));
                    for (i, c) in bucket {
                        f(i, c);
                    }
                });
            }
        });
    }

    /// Raw fork-join escape hatch for shapes `run`/`par_chunks` can't
    /// express (heterogeneous task sets). Serial when the pool is width-1
    /// or the caller is already a pool worker is NOT applied here — the
    /// closure decides what to spawn, capped at [`Pool::threads`] tasks
    /// by contract (asserted nowhere; prefer `run` when a cap matters).
    pub fn scope<'env, F, T>(&self, f: F) -> T
    where
        F: for<'scope> FnOnce(&'scope std::thread::Scope<'scope, 'env>) -> T,
    {
        std::thread::scope(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_returns_results_in_order() {
        for threads in [1, 2, 4, 9] {
            let pool = Pool::new(threads);
            let out = pool.run(23, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>(),
                       "threads={threads}");
        }
        assert!(Pool::new(4).run(0, |i| i).is_empty());
    }

    #[test]
    fn run_borrows_caller_state() {
        let data: Vec<u64> = (0..100).collect();
        let sums = Pool::new(3).run(10, |i| {
            data[i * 10..(i + 1) * 10].iter().sum::<u64>()
        });
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn par_chunks_writes_every_chunk() {
        let mut v = vec![0usize; 37];
        Pool::new(4).par_chunks(&mut v, 5, |ci, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = ci * 5 + k;
            }
        });
        assert_eq!(v, (0..37).collect::<Vec<_>>());
    }

    #[test]
    fn nested_run_degrades_to_serial() {
        let pool = Pool::new(4);
        let nested = pool.run(4, |_| {
            assert!(Pool::in_worker());
            // nested call must not deadlock or explode; serial fallback
            Pool::new(4).run(3, |j| j + 1)
        });
        for v in nested {
            assert_eq!(v, vec![1, 2, 3]);
        }
        assert!(!Pool::in_worker(), "flag is per-worker, not the caller");
    }

    #[test]
    fn scope_joins_heterogeneous_tasks() {
        let pool = Pool::new(2);
        let mut left = 0u64;
        let mut right = String::new();
        pool.scope(|s| {
            s.spawn(|| left = 41 + 1);
            s.spawn(|| right.push_str("done"));
        });
        assert_eq!(left, 42);
        assert_eq!(right, "done");
    }

    #[test]
    fn env_override_parses() {
        // configured_threads falls back to available_parallelism; the
        // global override wins afterwards
        set_global_threads(3);
        assert_eq!(Pool::global().threads(), 3);
        set_global_threads(1);
        assert_eq!(Pool::global().threads(), 1);
        // restore discovery default for other tests in this process
        set_global_threads(configured_threads());
    }
}
