//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! `run_cases` drives a closure over N seeded cases; on failure it reports
//! the failing seed so the case is exactly reproducible. Combined with the
//! deterministic [`crate::util::rng::Rng`], this covers the shrinking-free
//! 80% of what proptest gives us: randomized coverage with reproducibility.

use crate::util::rng::Rng;

/// Run `n` randomized cases. The closure gets a per-case RNG and the case
/// index; it returns Err(msg) to fail. Panics with seed info on failure.
pub fn run_cases<F>(name: &str, n: usize, base_seed: u64, f: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..n {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng, case) {
            panic!("property '{name}' failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing Result for use inside run_cases closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

/// Sample a dimension in [lo, hi].
pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        run_cases("counting", 17, 1, |_rng, _i| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "failed")]
    fn reports_failure() {
        run_cases("always-fails", 3, 2, |_rng, i| {
            if i == 2 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let collect = |seed| {
            let out = std::cell::RefCell::new(Vec::new());
            run_cases("det", 5, seed, |rng, _| {
                out.borrow_mut().push(rng.next_u64());
                Ok(())
            });
            out.into_inner()
        };
        assert_eq!(collect(9), collect(9));
        assert_ne!(collect(9), collect(10));
    }
}
