//! Deterministic RNG substrate: xoshiro256++ seeded via SplitMix64,
//! Gaussian sampling (Box–Muller), and the Wishart-correlated problem
//! generators used by the appendix figures (Figs 7–16: "correlation is
//! sampled from Wishart distribution with covariance of identity or
//! off-diagonal decaying of 0.9 factor").

use crate::tensor::Matrix;

/// xoshiro256++ — fast, high-quality, reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm),
                 splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data_mut() {
            *v = self.normal();
        }
        m
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            p.swap(i, self.below(i + 1));
        }
        p
    }
}

/// Σ with Σᵢⱼ = decay^|i−j| — the appendix figures' base covariance.
pub fn decaying_covariance(d: usize, decay: f64) -> Matrix {
    let mut c = Matrix::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            c[(i, j)] = decay.powi((i as i64 - j as i64).unsigned_abs() as i32);
        }
    }
    c
}

/// Wishart sample with scale Σ and `dof` degrees of freedom, normalized:
/// C = (L G)(L G)ᵀ / dof where Σ = L Lᵀ.
pub fn wishart(rng: &mut Rng, sigma: &Matrix, dof: usize) -> Matrix {
    let l = crate::tensor::linalg::cholesky(sigma)
        .expect("wishart scale must be PD");
    let g = rng.normal_matrix(sigma.rows(), dof);
    let lg = l.matmul(&g);
    let mut c = lg.matmul_bt(&lg);
    c.scale_inplace(1.0 / dof as f64);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(57);
        let mut seen = vec![false; 57];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn wishart_is_psd_and_near_sigma() {
        let mut r = Rng::new(11);
        let sigma = decaying_covariance(16, 0.9);
        let c = wishart(&mut r, &sigma, 1024);
        // symmetric
        for i in 0..16 {
            for j in 0..16 {
                assert!((c[(i, j)] - c[(j, i)]).abs() < 1e-12);
            }
        }
        // concentrates around sigma for large dof
        let mut err = 0.0;
        for i in 0..16 {
            for j in 0..16 {
                err += (c[(i, j)] - sigma[(i, j)]).powi(2);
            }
        }
        assert!(err.sqrt() < 1.5, "deviation {err}");
    }
}
