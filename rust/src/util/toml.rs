//! TOML-lite parser — the coordinator's config-file substrate.
//!
//! Supports the subset a deployment config needs: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! bool / homogeneous-array values, `#` comments. Produces a flat
//! `section.key -> Value` map (dotted paths).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }
}

pub type Table = BTreeMap<String, Value>;

pub fn parse(text: &str) -> Result<Table> {
    let mut out = Table::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: bad section header", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {}", lineno + 1, e))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full, val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")
            .replace("\\n", "\n")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>> =
            split_top(inner).iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    bail!("cannot parse value {s:?}")
}

fn split_top(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = parse(
            "# top comment\n\
             title = \"latentllm\"\n\
             [serve]\n\
             max_batch = 8   # inline comment\n\
             max_wait_ms = 5.5\n\
             methods = [\"plain\", \"latentllm\"]\n\
             verbose = false\n\
             [serve.deep]\n\
             x = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(t["title"].as_str().unwrap(), "latentllm");
        assert_eq!(t["serve.max_batch"].as_i64().unwrap(), 8);
        assert_eq!(t["serve.max_wait_ms"].as_f64().unwrap(), 5.5);
        assert_eq!(t["serve.verbose"].as_bool().unwrap(), false);
        match &t["serve.methods"] {
            Value::Arr(a) => assert_eq!(a.len(), 2),
            _ => panic!(),
        }
        match &t["serve.deep.x"] {
            Value::Arr(a) => assert_eq!(a[2], Value::Int(3)),
            _ => panic!(),
        }
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("k = [1, \"x\"\n").is_err());
    }
}
