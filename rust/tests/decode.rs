//! Incremental-decode tests: the cached prefill+step path must be
//! token-for-token identical to the full-window recompute reference on
//! dense *and* latent programs, sessions must enforce their lifecycle,
//! and the server's generate lane must admit/evict real session state
//! against the KV byte budget without poisoning neighbouring requests.

use std::path::PathBuf;

use latentllm::coordinator::batcher::BatcherConfig;
use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::coordinator::router::{ModelVariant, Policy, Router};
use latentllm::coordinator::scheduler::SchedulerConfig;
use latentllm::coordinator::server::{Drain, GenerateParams, ScoreParams,
                                     ServeError, Server, ServerConfig};
use latentllm::data::synth::{latent_demo_ranks, write_test_artifacts};
use latentllm::eval::generate::{generate, GenerateOpts};
use latentllm::model::config::MiniConfig;
use latentllm::model::Weights;
use latentllm::runtime::decode::BatchedDecodeState;
use latentllm::runtime::Engine;
use latentllm::Layout;

const TINY: MiniConfig = MiniConfig {
    name: "tiny", vocab: 48, d: 16, n_layers: 2, n_heads: 2,
    d_i: 32, max_len: 32,
};
const SEQ: usize = 32; // manifest seq_len == cfg.max_len
const BATCH: usize = 8;

/// Synthesize a full artifacts dir in a fresh tempdir; returns
/// (dir, latent tag).
fn synth(tag: &str) -> (PathBuf, String) {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_decode_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let latent_tag = write_test_artifacts(&dir, &TINY, 91).unwrap();
    (dir, latent_tag)
}

fn opts(max_new: usize, temperature: f64, use_cache: bool) -> GenerateOpts {
    GenerateOpts { max_new, temperature, seed: 5, use_cache }
}

fn prompts() -> Vec<Vec<i32>> {
    vec![
        vec![1, 2, 3],
        vec![7, 11, 13, 17, 19],
        vec![40, 2, 40, 2],
    ]
}

#[test]
fn cached_decode_matches_recompute_dense_and_latent() {
    let (art, tag) = synth("equiv");
    let engine = Engine::new(&art).unwrap();
    let cases = [
        (format!("step_{}", TINY.name),
         Weights::load(art.join(format!("model_{}.ltw", TINY.name)))
             .unwrap()),
        (format!("latent_step_{tag}"),
         Weights::load(art.join(format!("latent_model_{tag}.ltw")))
             .unwrap()),
    ];
    for (program, weights) in &cases {
        // greedy: the acceptance criterion — token-for-token identical
        let cached = generate(&engine, program, weights, &prompts(), BATCH,
                              SEQ, TINY.vocab, &opts(10, 0.0, true))
            .unwrap();
        let recompute = generate(&engine, program, weights, &prompts(),
                                 BATCH, SEQ, TINY.vocab,
                                 &opts(10, 0.0, false))
            .unwrap();
        assert_eq!(cached.sequences, recompute.sequences,
                   "{program}: greedy cached vs recompute diverged");
        assert!(cached.peak_cache_elements > 0,
                "{program}: cached path must hold real state");
        assert_eq!(recompute.peak_cache_elements, 0);

        // temperature sampling: both modes consume the RNG lane-major,
        // so the sampled sequences agree too
        let c = generate(&engine, program, weights, &prompts(), BATCH, SEQ,
                         TINY.vocab, &opts(8, 0.8, true)).unwrap();
        let r = generate(&engine, program, weights, &prompts(), BATCH, SEQ,
                         TINY.vocab, &opts(8, 0.8, false)).unwrap();
        assert_eq!(c.sequences, r.sequences,
                   "{program}: sampled cached vs recompute diverged");
    }
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn session_logits_match_step_program_exactly() {
    // drive the session API directly: prefill+step logits must equal the
    // full-window step program's next-token row at every position.
    let (art, _tag) = synth("logits");
    let engine = Engine::new(&art).unwrap();
    let weights = Weights::load(
        art.join(format!("model_{}.ltw", TINY.name))).unwrap();
    let prog = engine.program(&format!("step_{}", TINY.name)).unwrap();
    let seq: Vec<i32> = (0..12).map(|i| (i * 5) % TINY.vocab as i32)
        .collect();
    let mut session = prog.decode_session(&weights).unwrap();
    let mut got = vec![session.prefill(&seq[..4]).unwrap()];
    for &t in &seq[4..] {
        got.push(session.step(t).unwrap());
    }
    for (n, got_row) in got.iter().enumerate() {
        let len = 4 + n;
        let mut flat = vec![0i32; SEQ];
        flat[..len].copy_from_slice(&seq[..len]);
        let want = prog.run_f32(
            &[Engine::i32_input(&[1, SEQ], flat),
              Engine::i32_input(&[1], vec![len as i32])],
            &weights).unwrap();
        assert_eq!(got_row, &want,
                   "logits after {len} tokens diverged from the program");
    }
    assert_eq!(session.cached_tokens(), seq.len());
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn session_lifecycle_and_footprint() {
    let (art, tag) = synth("lifecycle");
    let engine = Engine::new(&art).unwrap();
    let dense_w = Weights::load(
        art.join(format!("model_{}.ltw", TINY.name))).unwrap();
    let latent_w = Weights::load(
        art.join(format!("latent_model_{tag}.ltw"))).unwrap();
    let dense_prog = engine.program(&format!("step_{}", TINY.name)).unwrap();
    let latent_prog = engine.program(&format!("latent_step_{tag}"))
        .unwrap();

    // score programs have no incremental semantics
    let score = engine.program(&format!("score_{}", TINY.name)).unwrap();
    assert!(score.decode_session(&dense_w).is_err());

    let mut s = dense_prog.decode_session(&dense_w).unwrap();
    assert!(s.prefill(&[]).is_err(), "empty prefill must error");
    assert!(s.step(1).is_err(), "step before prefill must error");
    s.prefill(&[1, 2, 3, 4]).unwrap();
    assert!(s.prefill(&[1]).is_err(), "double prefill must error");
    assert_eq!(s.cached_tokens(), 4);
    assert_eq!(s.max_tokens(), TINY.max_len,
               "capacity must be the positional table");
    // dense footprint: 2·d per token per layer, exactly
    assert_eq!(s.cache_elements(), 2 * TINY.d * TINY.n_layers * 4);
    assert_eq!(s.cache_kind(), CacheKind::Dense { d: TINY.d });
    assert_eq!(s.n_layers(), TINY.n_layers);

    // a session is windowless but bounded by the positional table
    for t in 0..(TINY.max_len - 4) {
        s.step((t % 7) as i32).unwrap();
    }
    let err = s.step(0).unwrap_err();
    assert!(format!("{err:#}").contains("positional table"),
            "overflow must name the bound: {err:#}");

    // latent footprint: r_k + r_v per token per layer — the paper's
    // compression of the cache itself
    let (rk, rv) = latent_demo_ranks(TINY.d);
    let mut s = latent_prog.decode_session(&latent_w).unwrap();
    s.prefill(&[1, 2, 3, 4]).unwrap();
    assert_eq!(s.cache_elements(), (rk + rv) * TINY.n_layers * 4);
    assert_eq!(s.cache_kind(), CacheKind::Latent { rk, rv });
    assert!(s.cache_elements()
            < 2 * TINY.d * TINY.n_layers * 4,
            "latent cache must be smaller than dense at equal tokens");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn generate_rejects_bad_prompt_sets() {
    let (art, _tag) = synth("badprompts");
    let engine = Engine::new(&art).unwrap();
    let weights = Weights::load(
        art.join(format!("model_{}.ltw", TINY.name))).unwrap();
    let program = format!("step_{}", TINY.name);
    for use_cache in [true, false] {
        let o = opts(4, 0.0, use_cache);
        let empty: Vec<Vec<i32>> = vec![];
        assert!(generate(&engine, &program, &weights, &empty, BATCH, SEQ,
                         TINY.vocab, &o).is_err(),
                "no prompts must error");
        let holes = vec![vec![1, 2], vec![]];
        let err = generate(&engine, &program, &weights, &holes, BATCH, SEQ,
                           TINY.vocab, &o).unwrap_err();
        assert!(format!("{err:#}").contains("prompt 1 is empty"),
                "bad error: {err:#}");
        let too_many: Vec<Vec<i32>> = (0..BATCH + 1).map(|_| vec![1])
            .collect();
        let err = generate(&engine, &program, &weights, &too_many, BATCH,
                           SEQ, TINY.vocab, &o).unwrap_err();
        assert!(format!("{err:#}").contains("exceed the program batch"),
                "bad error: {err:#}");
    }
    std::fs::remove_dir_all(&art).ok();
}

/// Dense-variant server; `sched: None` = the sequential PR 4 decode
/// path (the equivalence oracle the scheduler tests pin against).
fn tiny_server(art: PathBuf, budget: usize, workers: usize) -> Server {
    tiny_server_with(art, budget, workers, None, "dense")
}

/// Server over one tiny variant ("dense" or "latent") with an optional
/// continuous-batching scheduler. One variant keeps routing out of the
/// picture so token streams are attributable.
fn tiny_server_with(art: PathBuf, budget: usize, workers: usize,
                    sched: Option<SchedulerConfig>, variant: &str)
                    -> Server {
    tiny_server_traced(art, budget, workers, sched, variant, true)
}

/// Like [`tiny_server_with`] but with request tracing switchable — the
/// tracing-identity test runs the same traffic with it on and off.
fn tiny_server_traced(art: PathBuf, budget: usize, workers: usize,
                      sched: Option<SchedulerConfig>, variant: &str,
                      trace: bool) -> Server {
    let tag = latent_tag(&art);
    let block_tokens = sched.map(|s| s.block_tokens)
        .unwrap_or(latentllm::coordinator::kvcache::DEFAULT_BLOCK_TOKENS);
    let (rk, rv) = latent_demo_ranks(TINY.d);
    let v = if variant == "latent" {
        ModelVariant {
            name: "latent".to_string(),
            score_program: format!("latent_score_{tag}"),
            step_program: format!("latent_step_{tag}"),
            weights: std::sync::Arc::new(Weights::load(
                art.join(format!("latent_model_{tag}.ltw"))).unwrap()),
            cache: KvCacheManager::with_block_tokens(
                CacheKind::Latent { rk, rv }, TINY.n_layers, 2, budget,
                block_tokens),
        }
    } else {
        ModelVariant {
            name: "dense".to_string(),
            score_program: format!("score_{}", TINY.name),
            step_program: format!("step_{}", TINY.name),
            weights: std::sync::Arc::new(Weights::load(
                art.join(format!("model_{}.ltw", TINY.name))).unwrap()),
            cache: KvCacheManager::with_block_tokens(
                CacheKind::Dense { d: TINY.d }, TINY.n_layers, 2, budget,
                block_tokens),
        }
    };
    Server::start(
        art,
        Router::new(vec![v], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers,
            sched,
            trace,
        })
        .expect("server start")
}

/// The latent demo tag recorded in a synthesized artifacts manifest.
fn latent_tag(art: &std::path::Path) -> String {
    let engine = Engine::new(art).unwrap();
    engine.manifest().path(&["latent_demo", "tag"])
        .and_then(|v| v.as_str()).expect("latent_demo tag").to_string()
}

#[test]
fn server_decodes_alongside_score_batches() {
    let (art, _tag) = synth("servegen");
    let engine = Engine::new(&art).unwrap();
    let weights = Weights::load(
        art.join(format!("model_{}.ltw", TINY.name))).unwrap();
    let server = tiny_server(art.clone(), 8 << 20, 2);
    let timeout = std::time::Duration::from_secs(60);

    let prompt = vec![3, 5, 7, 9];
    let gen_rx = server.submit_generate(GenerateParams {
        prompt: prompt.clone(), max_new: 6, temperature: 0.0, seed: 0,
    }).expect("submit_generate");
    let score_rxs: Vec<_> = (0..5)
        .map(|_| server.submit_score(ScoreParams {
            tokens: vec![1, 2, 3, 4],
        }).expect("submit"))
        .collect();

    let resp = gen_rx.recv_timeout(timeout).expect("gen response");
    assert!(resp.error().is_none(), "decode failed: {:?}", resp.error());
    assert_eq!(resp.tokens().len(), 6);
    assert_eq!(resp.variant, "dense");
    // the served continuation is exactly the eval-path greedy decode
    let want = generate(&engine, &format!("step_{}", TINY.name), &weights,
                        &[prompt.clone()], BATCH, SEQ, TINY.vocab,
                        &opts(6, 0.0, true)).unwrap();
    assert_eq!(resp.tokens(), &want.sequences[0][prompt.len()..]);
    for rx in score_rxs {
        let r = rx.recv_timeout(timeout).expect("score response");
        assert!(r.error().is_none());
        assert!(r.nll().is_finite());
    }

    // malformed decode requests get typed errors, not dead workers
    let bad = server.submit_generate(GenerateParams {
        prompt: vec![], max_new: 4, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = bad.recv_timeout(timeout).expect("error response");
    assert!(matches!(r.result, Err(ServeError::Empty)), "{:?}", r.error());
    let long = server.submit_generate(GenerateParams {
        prompt: vec![1; SEQ + 1], max_new: 4, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = long.recv_timeout(timeout).expect("error response");
    assert!(r.error().is_some());
    // a request that would overflow the model context mid-decode is
    // rejected before the prefill is paid for
    let overshoot = server.submit_generate(GenerateParams {
        prompt: vec![1, 2, 3, 4], max_new: SEQ, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = overshoot.recv_timeout(timeout).expect("error response");
    assert!(matches!(r.result, Err(ServeError::TooLong { .. })),
            "{:?}", r.error());
    assert!(r.error().unwrap_or_default().contains("context holds"),
            "{:?}", r.error());
    assert!(!r.is_evicted());

    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("gen_requests"), 4);
    assert_eq!(m.counter("gen_tokens"), 6);
    assert_eq!(m.counter("gen_evictions"), 0);
    assert!(m.gauge("cache_bytes_peak") > 0,
            "admission must be visible in the cache gauge");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn eviction_under_tight_budget_errors_one_lane_only() {
    let (art, _tag) = synth("evict");
    // bytes/token = 2·d·2B·n_layers = 128; budget of 8 tokens: a 4-token
    // prompt admits, but decoding 20 more must hit the wall mid-flight
    let bpt = 2 * TINY.d * 2 * TINY.n_layers;
    let server = tiny_server(art.clone(), 8 * bpt, 1);
    let timeout = std::time::Duration::from_secs(60);

    let rx = server.submit_generate(GenerateParams {
        prompt: vec![1, 2, 3, 4], max_new: 20, temperature: 0.0, seed: 0,
    }).unwrap();
    let resp = rx.recv_timeout(timeout).expect("response");
    assert!(resp.is_evicted(),
            "budget exhaustion must evict: {:?}", resp.error());
    assert!(resp.error().unwrap_or_default().contains("evicted"),
            "{:?}", resp.error());

    // the eviction returned every byte: a request needing the whole
    // budget must now succeed — no poisoned lane, no leaked reservation
    let rx = server.submit_generate(GenerateParams {
        prompt: vec![1, 2, 3, 4], max_new: 4, temperature: 0.0, seed: 0,
    }).unwrap();
    let resp = rx.recv_timeout(timeout).expect("response");
    assert!(resp.error().is_none(),
            "post-eviction decode failed: {:?}", resp.error());
    assert_eq!(resp.tokens().len(), 4);

    // and score traffic on the same worker still flows
    let rx = server.submit_score(ScoreParams { tokens: vec![2, 4, 6] })
        .unwrap();
    let r = rx.recv_timeout(timeout).expect("score response");
    assert!(r.error().is_none());

    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("gen_evictions"), 1);
    assert_eq!(m.counter("worker_0_evictions"), 1);
    std::fs::remove_dir_all(&art).ok();
}

/// Mixed greedy + sampled decode traffic with per-request seeds.
fn sched_requests() -> Vec<GenerateParams> {
    vec![
        GenerateParams { prompt: vec![1, 2, 3], max_new: 8,
                         temperature: 0.0, seed: 0 },
        GenerateParams { prompt: vec![7, 11, 13, 17], max_new: 10,
                         temperature: 0.8, seed: 21 },
        GenerateParams { prompt: vec![40, 2], max_new: 6,
                         temperature: 0.0, seed: 0 },
        GenerateParams { prompt: vec![5, 9, 4, 33, 8], max_new: 9,
                         temperature: 0.6, seed: 99 },
        GenerateParams { prompt: vec![3, 3, 3], max_new: 7,
                         temperature: 0.0, seed: 0 },
    ]
}

fn run_decodes(server: &Server, reqs: &[GenerateParams])
               -> Vec<(Vec<i32>, Option<String>, bool)> {
    let timeout = std::time::Duration::from_secs(120);
    let rxs: Vec<_> = reqs.iter()
        .map(|r| server.submit_generate(r.clone()).expect("submit"))
        .collect();
    rxs.into_iter()
        .map(|rx| {
            let r = rx.recv_timeout(timeout).expect("gen response");
            (r.tokens().to_vec(), r.error(), r.is_evicted())
        })
        .collect()
}

#[test]
fn scheduler_decode_is_token_identical_to_sequential_sessions() {
    // the acceptance criterion: continuous batching (greedy AND
    // sampled) must emit exactly the sequential path's tokens, on the
    // dense and the latent program — batch composition must not be able
    // to leak between sequences.
    let (art, _tag) = synth("schedeq");
    let reqs = sched_requests();
    for variant in ["dense", "latent"] {
        let sequential = tiny_server_with(art.clone(), 8 << 20, 1, None,
                                          variant);
        let want = run_decodes(&sequential, &reqs);
        sequential.shutdown(Drain::Graceful);
        for (t, err, _) in &want {
            assert!(err.is_none(), "{variant} sequential failed: {err:?}");
            assert!(!t.is_empty());
        }
        let sched = tiny_server_with(
            art.clone(), 8 << 20, 1,
            Some(SchedulerConfig { max_live: 4, block_tokens: 2,
                                   prefill_chunk: 2, fused: true }),
            variant);
        let got = run_decodes(&sched, &reqs);
        let m = sched.shutdown(Drain::Graceful);
        assert_eq!(got, want,
                   "{variant}: scheduler diverged from sequential");
        assert_eq!(m.counter("gen_requests"), reqs.len() as u64);
        assert!(m.counter("sched_steps") > 0, "steps must be batched");
        assert!(m.gauge("live_sessions_peak") >= 2,
                "{variant}: sessions must actually overlap");
    }
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn scheduler_preempts_requeues_and_stays_token_identical() {
    // tight page pool: three sessions admit (2 blocks each) but cannot
    // all grow to completion, so the newest gets preempted — its pages
    // freed, its request requeued — and resumes by re-prefilling
    // prompt ++ generated. Every request still finishes with exactly
    // the tokens an unconstrained sequential server emits, and nothing
    // is evicted-errored (each fits the pool alone).
    let (art, _tag) = synth("schedpre");
    let reqs = sched_requests();
    let oracle = tiny_server(art.clone(), 8 << 20, 1);
    let want = run_decodes(&oracle, &reqs);
    oracle.shutdown(Drain::Graceful);
    // dense bytes/token = 2·16·2B·2L = 128; 2-token blocks of 256 B.
    // 12 blocks = 24 tokens: each request needs ≤ 13 cached tokens
    // (prompt+max_new-1 ≤ 8 blocks), so any one fits alone but three
    // cannot finish together.
    let bpt = 2 * TINY.d * 2 * TINY.n_layers;
    let sched = tiny_server_with(
        art.clone(), 12 * 2 * bpt, 1,
        Some(SchedulerConfig { max_live: 3, block_tokens: 2,
                               prefill_chunk: 4, fused: true }),
        "dense");
    let got = run_decodes(&sched, &reqs);
    let m = sched.shutdown(Drain::Graceful);
    assert_eq!(got, want,
               "preempt→requeue→resume must not change a single token");
    assert!(m.counter("gen_preemptions") >= 1,
            "the tight pool must actually preempt \
             (preemptions={}, evictions={})",
            m.counter("gen_preemptions"), m.counter("gen_evictions"));
    assert_eq!(m.counter("gen_evictions"), 0,
               "requests that fit alone must never be evicted-errored");
    assert!(m.counter("gen_resumed_ok") >= 1,
            "a preempted request must resume and finish");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn scheduler_rejects_only_what_can_never_fit() {
    let (art, _tag) = synth("schednofit");
    // 2 blocks of 2 tokens = 4-token pool
    let bpt = 2 * TINY.d * 2 * TINY.n_layers;
    let sched_cfg = SchedulerConfig { max_live: 2, block_tokens: 2,
                                      prefill_chunk: 4, fused: true };
    let server = tiny_server_with(art.clone(), 4 * bpt, 1,
                                  Some(sched_cfg), "dense");
    let timeout = std::time::Duration::from_secs(60);
    // needs 3 + 9 = 12 positions > 4-token pool: evicted-reject
    let rx = server.submit_generate(GenerateParams {
        prompt: vec![1, 2, 3], max_new: 10, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = rx.recv_timeout(timeout).expect("response");
    assert!(r.is_evicted(), "can-never-fit must reject as evicted: {:?}",
            r.error());
    assert!(r.error().unwrap_or_default().contains("never fit"),
            "{:?}", r.error());
    // a request that fits exactly still completes
    let rx = server.submit_generate(GenerateParams {
        prompt: vec![1, 2], max_new: 3, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = rx.recv_timeout(timeout).expect("response");
    assert!(r.error().is_none(), "{:?}", r.error());
    assert_eq!(r.tokens().len(), 3);
    // empty prompts and positional-table overshoots error like the
    // sequential path
    let rx = server.submit_generate(GenerateParams {
        prompt: vec![], max_new: 2, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = rx.recv_timeout(timeout).expect("response");
    assert!(matches!(r.result, Err(ServeError::Empty)), "{:?}", r.error());
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("gen_evictions"), 1);
    assert_eq!(m.counter("gen_tokens"), 3);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn scheduler_reroutes_off_a_pool_that_can_never_hold_it() {
    // two pools of very different sizes share one server: a request the
    // small pool can never hold must not be terminally rejected there —
    // the scheduler learns the real-footprint misfit, excludes that
    // variant from routing, and the request completes on the big pool.
    let (art, _tag) = synth("schedreroute");
    let weights = std::sync::Arc::new(Weights::load(
        art.join(format!("model_{}.ltw", TINY.name))).unwrap());
    let bpt = 2 * TINY.d * 2 * TINY.n_layers; // 128 B/token
    let mk_variant = |name: &str, blocks: usize| ModelVariant {
        name: name.to_string(),
        score_program: format!("score_{}", TINY.name),
        step_program: format!("step_{}", TINY.name),
        weights: weights.clone(),
        cache: KvCacheManager::with_block_tokens(
            CacheKind::Dense { d: TINY.d }, TINY.n_layers, 2,
            blocks * 2 * bpt, 2), // 2-token blocks
    };
    let server = Server::start(
        art.clone(),
        // round-robin places the first request on "small" first
        Router::new(vec![mk_variant("small", 4), mk_variant("big", 12)],
                    Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 1,
            sched: Some(SchedulerConfig { max_live: 2, block_tokens: 2,
                                          prefill_chunk: 4,
                                          fused: true }),
            trace: true,
        })
        .expect("server start");
    let timeout = std::time::Duration::from_secs(120);
    // needs 4 + 10 - 1 = 13 tokens = 7 two-token blocks: never fits the
    // 4-block pool, comfortably fits the 12-block one
    let rx = server.submit_generate(GenerateParams {
        prompt: vec![1, 2, 3, 4], max_new: 10, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = rx.recv_timeout(timeout).expect("response");
    assert!(r.error().is_none(),
            "a pool that fits elsewhere must not reject: {:?}", r.error());
    assert_eq!(r.variant, "big", "must complete on the fitting pool");
    assert_eq!(r.tokens().len(), 10);
    // a request no pool can ever hold is still terminally rejected
    // (29 tokens: inside the positional table, beyond both pools)
    let rx = server.submit_generate(GenerateParams {
        prompt: vec![1, 2, 3, 4], max_new: 26, temperature: 0.0, seed: 0,
    }).unwrap();
    let r = rx.recv_timeout(timeout).expect("response");
    assert!(r.is_evicted(), "nowhere-fits must reject as evicted: {:?}",
            r.error());
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("gen_evictions"), 1);
    std::fs::remove_dir_all(&art).ok();
}

/// Shared-prefix decode traffic: every prompt starts with the same 8
/// tokens (4 full blocks at block_tokens=2) and diverges after —
/// greedy and sampled, with one pair diverging mid-chain so partial
/// hits are exercised too.
fn shared_prefix_requests() -> Vec<GenerateParams> {
    let head: Vec<i32> = vec![2, 4, 6, 8, 1, 3, 5, 7];
    let mk = |tail: &[i32], max_new: usize, temperature: f64, seed: u64| {
        let mut prompt = head.clone();
        prompt.extend_from_slice(tail);
        GenerateParams { prompt, max_new, temperature, seed }
    };
    vec![
        mk(&[9], 6, 0.0, 0),
        mk(&[10, 11], 7, 0.8, 21),
        mk(&[12, 13, 14], 5, 0.0, 0),
        mk(&[9, 30], 6, 0.6, 77), // shares one extra block with req 0
    ]
}

#[test]
fn prefix_cache_reuse_is_token_identical_warm_and_cold() {
    // the tentpole acceptance bar: scheduler decode with cold, warm and
    // partially-hit prefixes must emit exactly the sequential path's
    // tokens, dense AND latent, greedy AND sampled. The second batch on
    // the same server re-runs every request against a hot cache.
    let (art, _tag) = synth("prefixeq");
    let reqs = shared_prefix_requests();
    for variant in ["dense", "latent"] {
        let sequential = tiny_server_with(art.clone(), 8 << 20, 1, None,
                                          variant);
        let want = run_decodes(&sequential, &reqs);
        sequential.shutdown(Drain::Graceful);
        for (t, err, _) in &want {
            assert!(err.is_none(), "{variant} sequential failed: {err:?}");
            assert!(!t.is_empty());
        }
        let sched = tiny_server_with(
            art.clone(), 8 << 20, 1,
            Some(SchedulerConfig { max_live: 4, block_tokens: 2,
                                   prefill_chunk: 3, fused: true }),
            variant);
        let cold = run_decodes(&sched, &reqs);
        let warm = run_decodes(&sched, &reqs);
        let m = sched.shutdown(Drain::Graceful);
        assert_eq!(cold, want, "{variant}: cold prefix-cache run diverged");
        assert_eq!(warm, want, "{variant}: warm prefix-cache run diverged");
        // every warm request admits against blocks donated by the cold
        // batch (the 8-token head is 4 full blocks, under the feed-1 cap)
        assert!(m.counter("prefix_hits") >= reqs.len() as u64,
                "{variant}: warm batch must hit (hits={})",
                m.counter("prefix_hits"));
        assert!(m.counter("prefix_misses") >= 1,
                "{variant}: the cold batch must miss first");
        assert!(m.counter("prefix_saved_tokens") >= 8,
                "{variant}: a hit must save at least the shared head");
        assert!(m.gauge("prefix_blocks_cached_peak") > 0,
                "{variant}: donated blocks must be visible in the gauge");
    }
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn prefix_cache_preemption_cycle_stays_token_identical() {
    // shared-prefix traffic on a pool too small for all three sessions:
    // preempt→requeue→resume now re-admits THROUGH the prefix cache
    // (the victim's own donated prompt blocks are the likeliest hit),
    // and the token streams must still match an unconstrained
    // sequential server exactly.
    let (art, _tag) = synth("prefixpre");
    let head = [2i32, 4, 6, 8];
    let mk = |tail: &[i32], temperature: f64, seed: u64| {
        let mut prompt = head.to_vec();
        prompt.extend_from_slice(tail);
        GenerateParams { prompt, max_new: 8, temperature, seed }
    };
    let reqs = vec![mk(&[9, 11], 0.0, 0), mk(&[13, 15], 0.7, 33),
                    mk(&[17, 19], 0.0, 0)];
    let oracle = tiny_server(art.clone(), 8 << 20, 1);
    let want = run_decodes(&oracle, &reqs);
    oracle.shutdown(Drain::Graceful);
    for (t, err, _) in &want {
        assert!(err.is_none(), "sequential failed: {err:?}");
        assert!(!t.is_empty());
    }
    // each request needs 6 + 8 - 1 = 13 tokens = 7 two-token blocks:
    // any one fits a 12-block pool alone, three cannot finish together
    // even sharing the 2-block head (2 + 3·5 = 17 > 12)
    let bpt = 2 * TINY.d * 2 * TINY.n_layers;
    let sched = tiny_server_with(
        art.clone(), 12 * 2 * bpt, 1,
        Some(SchedulerConfig { max_live: 3, block_tokens: 2,
                               prefill_chunk: 4, fused: true }),
        "dense");
    let got = run_decodes(&sched, &reqs);
    let m = sched.shutdown(Drain::Graceful);
    assert_eq!(got, want,
               "prefix-cached preempt→requeue→resume changed a token");
    assert!(m.counter("gen_preemptions") >= 1,
            "the tight pool must actually preempt (preemptions={})",
            m.counter("gen_preemptions"));
    assert_eq!(m.counter("gen_evictions"), 0,
               "requests that fit alone must never be evicted-errored");
    assert!(m.counter("gen_resumed_ok") >= 1,
            "a preempted request must resume and finish");
    assert!(m.counter("prefix_misses") >= reqs.len() as u64,
            "cold admissions on a nominal-rate pool must count misses");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn disabling_the_prefix_cache_keeps_streams_identical() {
    // the kill switch (`serve --no-prefix-cache`): same traffic, cache
    // off — zero prefix counters, same tokens
    let (art, _tag) = synth("prefixoff");
    let reqs = shared_prefix_requests();
    let oracle = tiny_server(art.clone(), 8 << 20, 1);
    let want = run_decodes(&oracle, &reqs);
    oracle.shutdown(Drain::Graceful);
    let sched_cfg = SchedulerConfig { max_live: 4, block_tokens: 2,
                                      prefill_chunk: 3, fused: true };
    let mut cache = KvCacheManager::with_block_tokens(
        CacheKind::Dense { d: TINY.d }, TINY.n_layers, 2, 8 << 20,
        sched_cfg.block_tokens);
    cache.set_prefix_cache(false);
    let v = ModelVariant {
        name: "dense".to_string(),
        score_program: format!("score_{}", TINY.name),
        step_program: format!("step_{}", TINY.name),
        weights: std::sync::Arc::new(Weights::load(
            art.join(format!("model_{}.ltw", TINY.name))).unwrap()),
        cache,
    };
    let server = Server::start(
        art.clone(),
        Router::new(vec![v], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 1,
            sched: Some(sched_cfg),
            trace: true,
        })
        .expect("server start");
    let cold = run_decodes(&server, &reqs);
    let warm = run_decodes(&server, &reqs);
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(cold, want, "prefix-cache-off run diverged");
    assert_eq!(warm, want, "prefix-cache-off rerun diverged");
    assert_eq!(m.counter("prefix_hits"), 0, "off means no sharing");
    assert_eq!(m.counter("prefix_misses"), 0, "off means no lookups");
    assert_eq!(m.gauge("prefix_blocks_cached_peak"), 0);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn insert_prefilled_seeds_sessions_from_exported_blocks() {
    // the batch-seam entry the scheduler's admission path rests on:
    // a session seeded from exported prefix rows, fed only the suffix,
    // lands on bit-identical logits to a cold whole-prompt prefill
    let (art, _tag) = synth("insertpre");
    let engine = Engine::new(&art).unwrap();
    let weights = Weights::load(
        art.join(format!("model_{}.ltw", TINY.name))).unwrap();
    let prog = engine.program(&format!("step_{}", TINY.name)).unwrap();
    let seq: Vec<i32> = (0..10).map(|i| (i * 5 + 1) % TINY.vocab as i32)
        .collect();
    let mut donor = prog.decode_session(&weights).unwrap();
    let want = donor.prefill(&seq).unwrap();
    let snap = donor.export_prefix(6).unwrap();
    assert_eq!(snap.tokens, 6);
    let mut batch = BatchedDecodeState::new();
    let slot = batch.insert_prefilled(
        7, prog.decode_session(&weights).unwrap(), Some(&snap)).unwrap();
    let sess = batch.session_mut(slot).unwrap();
    let rows = sess.step_many(&seq[6..]).unwrap();
    assert_eq!(rows.last().unwrap(), &want,
               "adopted suffix must reach the cold prefill's logits");
    assert_eq!(sess.cached_tokens(), seq.len());
    // `None` behaves exactly like plain insert
    let slot2 = batch.insert_prefilled(
        8, prog.decode_session(&weights).unwrap(), None).unwrap();
    assert_ne!(slot, slot2);
    assert_eq!(batch.session_mut(slot2).unwrap().cached_tokens(), 0);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn step_many_chunks_match_single_steps_exactly() {
    // the batched-step seam itself: chunked prefill + step_many must
    // reproduce the one-token-at-a-time logits bit for bit (what makes
    // scheduler preemption/resume and prefill chunking token-safe).
    let (art, tag) = synth("stepmany");
    let engine = Engine::new(&art).unwrap();
    let cases = [
        (format!("step_{}", TINY.name),
         Weights::load(art.join(format!("model_{}.ltw", TINY.name)))
             .unwrap()),
        (format!("latent_step_{tag}"),
         Weights::load(art.join(format!("latent_model_{tag}.ltw")))
             .unwrap()),
    ];
    let seq: Vec<i32> = (0..14).map(|i| (i * 3) % TINY.vocab as i32)
        .collect();
    for (program, weights) in &cases {
        let prog = engine.program(program).unwrap();
        // reference: prefill 4, then 10 single steps
        let mut a = prog.decode_session(weights).unwrap();
        let mut want = vec![a.prefill(&seq[..4]).unwrap()];
        for &t in &seq[4..] {
            want.push(a.step(t).unwrap());
        }
        // chunked: prefill 2, then step_many in ragged chunks
        let mut b = prog.decode_session(weights).unwrap();
        let mut got = vec![b.prefill(&seq[..2]).unwrap()];
        for chunk in seq[2..].chunks(3) {
            got.extend(b.step_many(chunk).unwrap());
        }
        assert_eq!(b.cached_tokens(), seq.len());
        // the chunked path sees logits after EVERY token; the reference
        // after tokens 4.. — align on the common suffix
        assert_eq!(got.len(), seq.len() - 1);
        assert_eq!(want.len(), seq.len() - 3);
        assert_eq!(&got[2..], &want[..],
                   "{program}: chunked logits diverged from single steps");
        assert!(b.step_many(&[]).unwrap().is_empty());
    }
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn fused_batched_step_matches_per_session_across_layouts() {
    // the tentpole pin: a ≥2-wide step batch through the fused
    // one-GEMM-pass-per-layer path must be bit-identical to the
    // per-session loop — dense + latent programs, f64/f32/int8 weight
    // layouts, mixed prompt lengths, every round.
    let (art, tag) = synth("fusedlay");
    let engine = Engine::new(&art).unwrap();
    let cases = [
        (format!("step_{}", TINY.name),
         Weights::load(art.join(format!("model_{}.ltw", TINY.name)))
             .unwrap()),
        (format!("latent_step_{tag}"),
         Weights::load(art.join(format!("latent_model_{tag}.ltw")))
             .unwrap()),
    ];
    let prompts: [&[i32]; 3] = [&[1, 2, 3], &[7, 11, 13, 17, 19], &[40, 2]];
    for (program, base) in &cases {
        for layout in [Layout::DenseF64, Layout::PackedF32,
                       Layout::QuantI8] {
            let weights = if layout == Layout::DenseF64 {
                base.clone()
            } else {
                base.repack(layout, 16).unwrap()
            };
            let prog = engine.program(program).unwrap();
            let mut fused = BatchedDecodeState::new();
            let mut plain = BatchedDecodeState::new();
            plain.set_fused(false);
            let mut slots = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                let mut sa = prog.decode_session(&weights).unwrap();
                let mut sb = prog.decode_session(&weights).unwrap();
                assert_eq!(sa.prefill(p).unwrap(), sb.prefill(p).unwrap(),
                           "{program}: prefill must agree before stepping");
                let slot = fused.insert(i as u64, sa);
                assert_eq!(plain.insert(i as u64, sb), slot);
                slots.push(slot);
            }
            for round in 0..8usize {
                let steps: Vec<(usize, i32)> = slots.iter().enumerate()
                    .map(|(i, &s)| {
                        (s, ((round * 5 + i * 3) % TINY.vocab) as i32)
                    })
                    .collect();
                let a = fused.step_many(&steps);
                let b = plain.step_many(&steps);
                for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(ra.as_ref().unwrap(), rb.as_ref().unwrap(),
                               "{program} {}: row {i} diverged in round \
                                {round}", layout.name());
                }
            }
            assert_eq!(fused.fused_stats(), (8, 24),
                       "{program} {}: every round must take the fused \
                        path", layout.name());
            assert_eq!(plain.fused_stats(), (0, 0),
                       "the kill switch must keep the per-session loop");
        }
    }
    std::fs::remove_dir_all(&art).ok();
}

/// `names` must contain `want` as an ordered (not necessarily
/// contiguous) subsequence.
fn has_subsequence(names: &[&str], want: &[&str]) -> bool {
    let mut it = names.iter();
    want.iter().all(|w| it.any(|n| n == w))
}

#[test]
fn tracing_is_token_identical_and_pins_the_preemption_span_chain() {
    // tracing defaults on; it must be a pure observer. The same tight-
    // pool preemption workload runs traced and untraced and must emit
    // identical streams — and the traced run's ring must hold complete
    // span chains including the preempt→requeue→resume arc.
    let (art, _tag) = synth("traceeq");
    let reqs = sched_requests();
    let bpt = 2 * TINY.d * 2 * TINY.n_layers;
    let sched_cfg = SchedulerConfig { max_live: 3, block_tokens: 2,
                                      prefill_chunk: 4, fused: true };
    let traced = tiny_server_traced(art.clone(), 12 * 2 * bpt, 1,
                                    Some(sched_cfg), "dense", true);
    let got_traced = run_decodes(&traced, &reqs);
    // every response carries a timings summary when tracing is on
    let rx = traced.submit_generate(reqs[0].clone()).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(120))
        .unwrap();
    let t = resp.timings.expect("traced responses carry timings");
    assert_eq!(t.tokens, reqs[0].max_new as u64,
               "timings.tokens must equal delivered tokens");
    let completed = traced.traces.recent(64);
    let m = traced.shutdown(Drain::Graceful);
    assert!(m.counter("gen_preemptions") >= 1,
            "the tight pool must actually preempt");

    assert_eq!(completed.len(), reqs.len() + 1);
    let mut saw_preemption_arc = false;
    for c in &completed {
        assert_eq!(c.kind, "generate");
        assert!(!c.failed, "request {} failed in the trace ring", c.id);
        let names: Vec<&str> =
            c.events.iter().map(|e| e.kind.name()).collect();
        assert_eq!(names.first(), Some(&"queued"), "chain: {names:?}");
        assert_eq!(names.last(), Some(&"retired"), "chain: {names:?}");
        assert!(names.contains(&"admitted"), "chain: {names:?}");
        assert!(names.contains(&"step"), "chain: {names:?}");
        if c.timings.preemptions > 0 {
            assert!(has_subsequence(
                        &names,
                        &["preempted", "requeued", "resumed"]),
                    "preempted request missing the requeue arc: \
                     {names:?}");
            saw_preemption_arc = true;
        }
        assert!(c.timings.total_us
                >= c.timings.queue_us + c.timings.prefill_us,
                "phase times exceed the wall: {:?}", c.timings);
    }
    assert!(saw_preemption_arc,
            "at least one trace must record the preemption arc");
    let delivered: u64 = completed.iter().map(|c| c.timings.tokens).sum();
    let want_tokens: u64 = reqs.iter().map(|r| r.max_new as u64).sum();
    assert_eq!(delivered, want_tokens + reqs[0].max_new as u64);

    // tracing off: identical tokens, no timings, an empty ring
    let plain = tiny_server_traced(art.clone(), 12 * 2 * bpt, 1,
                                   Some(sched_cfg), "dense", false);
    let got_plain = run_decodes(&plain, &reqs);
    let rx = plain.submit_generate(reqs[0].clone()).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(120))
        .unwrap();
    assert!(resp.timings.is_none(), "untraced responses stay lean");
    assert!(plain.traces.is_empty(), "untraced runs record nothing");
    plain.shutdown(Drain::Graceful);
    assert_eq!(got_traced, got_plain,
               "tracing changed a token stream — it must be a pure \
                observer");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn warm_prefix_hits_show_up_in_traces_with_saved_tokens() {
    // a warm prefix-cache admission must be visible per-request: the
    // span chain records prefix_adopted with the tokens it skipped, and
    // the timings summary flags the hit.
    let (art, _tag) = synth("traceprefix");
    let reqs = shared_prefix_requests();
    let server = tiny_server_with(
        art.clone(), 8 << 20, 1,
        Some(SchedulerConfig { max_live: 4, block_tokens: 2,
                               prefill_chunk: 3, fused: true }),
        "dense");
    run_decodes(&server, &reqs); // cold: donates the shared head
    run_decodes(&server, &reqs); // warm: adopts it
    let warm = server.traces.recent(reqs.len());
    server.shutdown(Drain::Graceful);
    assert_eq!(warm.len(), reqs.len());
    let mut saved = 0u64;
    for c in &warm {
        assert!(c.timings.prefix_hit,
                "warm request {} missed the prefix cache", c.id);
        let adopted = c.events.iter()
            .find(|e| e.kind.name() == "prefix_adopted")
            .unwrap_or_else(|| panic!("no prefix_adopted event for {}",
                                      c.id));
        assert!(adopted.value >= 2,
                "a hit must adopt at least one full block");
        saved += c.prefix_saved_tokens;
    }
    assert!(saved >= 8 * reqs.len() as u64 - 8,
            "the shared 8-token head must dominate the savings \
             (saved={saved})");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn fused_kill_switch_keeps_streams_identical_and_is_observable() {
    // `--no-fused-step` parity: the same traffic through a fused and an
    // unfused scheduler must land on the sequential oracle's exact
    // tokens (greedy AND sampled, across preemptable mixed batches),
    // and the metrics must say which path ran.
    let (art, _tag) = synth("fusedkill");
    let reqs = sched_requests();
    for variant in ["dense", "latent"] {
        let oracle = tiny_server_with(art.clone(), 8 << 20, 1, None,
                                      variant);
        let want = run_decodes(&oracle, &reqs);
        oracle.shutdown(Drain::Graceful);
        for (t, err, _) in &want {
            assert!(err.is_none(), "{variant} sequential failed: {err:?}");
            assert!(!t.is_empty());
        }
        let mut metrics = Vec::new();
        for fused in [true, false] {
            let server = tiny_server_with(
                art.clone(), 8 << 20, 1,
                Some(SchedulerConfig { max_live: 4, block_tokens: 2,
                                       prefill_chunk: 2, fused }),
                variant);
            let got = run_decodes(&server, &reqs);
            let m = server.shutdown(Drain::Graceful);
            assert_eq!(got, want,
                       "{variant} fused={fused}: streams diverged");
            metrics.push(m);
        }
        assert!(metrics[0].counter("fused_batches") >= 1,
                "{variant}: ≥2-wide same-model batches must fuse");
        assert!(metrics[0].counter("fused_step_rows")
                >= 2 * metrics[0].counter("fused_batches"),
                "{variant}: fused batches hold ≥2 rows by construction");
        assert!(metrics[0].quantiles("step_us").is_some(),
                "{variant}: step latency must be observed");
        assert_eq!(metrics[1].counter("fused_batches"), 0,
                   "{variant}: the kill switch must keep fusion off");
        assert_eq!(metrics[1].counter("fused_step_rows"), 0);
    }
    std::fs::remove_dir_all(&art).ok();
}
