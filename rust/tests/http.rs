//! HTTP front-door tests over synthesized artifacts and raw
//! `std::net::TcpStream` clients: endpoint shapes, streamed-token
//! equivalence with the in-process decode, the error-status taxonomy,
//! Prometheus exposition, backpressure, and graceful drain under
//! in-flight generates (the zero-lost-requests criterion).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use latentllm::coordinator::batcher::BatcherConfig;
use latentllm::coordinator::http::{HttpConfig, HttpServer};
use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::coordinator::router::{ModelVariant, Policy, Router};
use latentllm::coordinator::scheduler::SchedulerConfig;
use latentllm::coordinator::server::{Drain, GenerateParams, ServeError,
                                     Server, ServerConfig};
use latentllm::data::synth::write_test_artifacts;
use latentllm::model::config::MiniConfig;
use latentllm::model::Weights;
use latentllm::util::json::{self, Value};

const TINY: MiniConfig = MiniConfig {
    name: "tiny", vocab: 48, d: 16, n_layers: 2, n_heads: 2,
    d_i: 32, max_len: 32,
};
const SEQ: usize = 32;

fn synth(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_http_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_test_artifacts(&dir, &TINY, 77).unwrap();
    dir
}

/// One dense tiny variant behind the coordinator; `sched` picks the
/// decode mode (None = sequential one-session-per-worker).
fn tiny_server(art: PathBuf, sched: Option<SchedulerConfig>)
               -> Arc<Server> {
    let block_tokens = sched.map(|s| s.block_tokens)
        .unwrap_or(latentllm::coordinator::kvcache::DEFAULT_BLOCK_TOKENS);
    let v = ModelVariant {
        name: "dense".to_string(),
        score_program: format!("score_{}", TINY.name),
        step_program: format!("step_{}", TINY.name),
        weights: Arc::new(Weights::load(
            art.join(format!("model_{}.ltw", TINY.name))).unwrap()),
        cache: KvCacheManager::with_block_tokens(
            CacheKind::Dense { d: TINY.d }, TINY.n_layers, 2, 8 << 20,
            block_tokens),
    };
    Arc::new(Server::start(
        art,
        Router::new(vec![v], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: 8,
            seq_len: SEQ,
            workers: 1,
            sched,
            trace: true,
        })
        .expect("server start"))
}

fn http_cfg() -> HttpConfig {
    HttpConfig { addr: "127.0.0.1:0".to_string(), ..HttpConfig::default() }
}

/// A parsed response off the wire: status, headers, de-chunked body.
struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Value {
        json::parse(&self.body).expect("response body is JSON")
    }

    /// `data:` payloads of a `text/event-stream` body, `[DONE]`
    /// included.
    fn events(&self) -> Vec<String> {
        self.body.split("\n\n")
            .filter_map(|ev| ev.trim().strip_prefix("data: "))
            .map(|s| s.to_string())
            .collect()
    }
}

fn dechunk(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut pos = 0;
    loop {
        let Some(nl) = raw[pos..].windows(2).position(|w| w == b"\r\n")
        else {
            panic!("chunked body missing size line");
        };
        let size = usize::from_str_radix(
            std::str::from_utf8(&raw[pos..pos + nl]).unwrap().trim(), 16)
            .expect("chunk size is hex");
        pos += nl + 2;
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&raw[pos..pos + size]);
        pos += size + 2; // skip the chunk's trailing CRLF
    }
}

/// Send one request with `Connection: close` and read the connection to
/// EOF. De-chunks `Transfer-Encoding: chunked` bodies.
fn roundtrip(addr: SocketAddr, method: &str, path: &str, body: &str)
             -> Reply {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    write!(s, "{method} {path} HTTP/1.1\r\nHost: test\r\n\
               Connection: close\r\nContent-Length: {}\r\n\r\n{body}",
           body.len())
        .expect("write request");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read response");
    parse_reply(&raw)
}

fn parse_reply(raw: &[u8]) -> Reply {
    let split = raw.windows(4).position(|w| w == b"\r\n\r\n")
        .expect("response has a header/body split");
    let head = std::str::from_utf8(&raw[..split]).expect("UTF-8 head");
    let mut lines = head.split("\r\n");
    let status: u16 = lines.next().expect("status line")
        .split_whitespace().nth(1).expect("status code")
        .parse().expect("numeric status");
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    let chunked = headers.iter().any(
        |(k, v)| k.eq_ignore_ascii_case("transfer-encoding")
            && v.eq_ignore_ascii_case("chunked"));
    let body = if chunked {
        dechunk(&raw[split + 4..])
    } else {
        raw[split + 4..].to_vec()
    };
    Reply { status, headers,
            body: String::from_utf8(body).expect("UTF-8 body") }
}

fn completion_body(prompt: &[i32], max_new: usize, temperature: f64,
                   seed: u64, stream: bool) -> String {
    let toks: Vec<String> =
        prompt.iter().map(|t| t.to_string()).collect();
    format!("{{\"prompt\": [{}], \"max_new\": {max_new}, \
             \"temperature\": {temperature}, \"seed\": {seed}, \
             \"stream\": {stream}}}", toks.join(", "))
}

/// Token list out of a completion reply's `"tokens"` array.
fn tokens_of(v: &Value) -> Vec<i32> {
    v.get("tokens").and_then(|t| t.as_arr()).expect("tokens array")
        .iter()
        .map(|t| t.as_f64().expect("numeric token") as i32)
        .collect()
}

#[test]
fn score_completion_and_health_roundtrip() {
    let art = synth("roundtrip");
    let server = tiny_server(art.clone(), None);
    let http = HttpServer::start(server.clone(), http_cfg()).unwrap();
    let addr = http.local_addr();

    let health = roundtrip(addr, "GET", "/healthz", "");
    assert_eq!(health.status, 200);
    assert_eq!(health.json().get("status").unwrap().as_str(),
               Some("ok"));

    let score = roundtrip(addr, "POST", "/v1/score",
                          "{\"tokens\": [1, 2, 3, 5, 7, 11]}");
    assert_eq!(score.status, 200, "score body: {}", score.body);
    let v = score.json();
    assert_eq!(v.get("object").unwrap().as_str(), Some("score"));
    assert!(v.get("nll").unwrap().as_f64().unwrap().is_finite());
    assert_eq!(v.get("variant").unwrap().as_str(), Some("dense"));

    // non-streamed completion matches the in-process typed API exactly
    let prompt = [1, 2, 3];
    let params = GenerateParams {
        prompt: prompt.to_vec(), max_new: 8, temperature: 0.0, seed: 0,
    };
    let want = server.submit_generate(params).unwrap()
        .recv_timeout(Duration::from_secs(60)).unwrap()
        .into_tokens();
    assert_eq!(want.len(), 8);
    let comp = roundtrip(addr, "POST", "/v1/completions",
                         &completion_body(&prompt, 8, 0.0, 0, false));
    assert_eq!(comp.status, 200, "completion body: {}", comp.body);
    let v = comp.json();
    assert_eq!(v.get("object").unwrap().as_str(), Some("completion"));
    assert_eq!(tokens_of(&v), want);

    http.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    let m = server.shutdown(Drain::Graceful);
    assert!(m.counter("http_requests") >= 3);
    assert_eq!(m.counter("http_5xx"), 0);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn streamed_tokens_match_sequential_reference() {
    let art = synth("stream");
    let server = tiny_server(art.clone(), None);
    let http = HttpServer::start(server.clone(), http_cfg()).unwrap();
    let addr = http.local_addr();

    // greedy and temperature-sampled; both are seeded and must stream
    // the exact token sequence the in-process sequential decode yields
    for (temperature, seed) in [(0.0, 0u64), (0.8, 17)] {
        let prompt = [7, 11, 13, 17];
        let params = GenerateParams {
            prompt: prompt.to_vec(), max_new: 10, temperature, seed,
        };
        let want = server.submit_generate(params).unwrap()
            .recv_timeout(Duration::from_secs(60)).unwrap()
            .into_tokens();
        assert_eq!(want.len(), 10);

        let reply = roundtrip(
            addr, "POST", "/v1/completions",
            &completion_body(&prompt, 10, temperature, seed, true));
        assert_eq!(reply.status, 200);
        assert_eq!(reply.header("transfer-encoding"), Some("chunked"));
        let events = reply.events();
        assert_eq!(events.last().map(|s| s.as_str()), Some("[DONE]"));
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in &events[..events.len() - 1] {
            let v = json::parse(ev).expect("event JSON");
            if let Some(t) = v.get("token").and_then(|t| t.as_f64()) {
                streamed.push(t as i32);
            } else {
                done = Some(v);
            }
        }
        assert_eq!(streamed, want,
                   "streamed tokens diverged at temperature \
                    {temperature}");
        let done = done.expect("terminal done event");
        assert!(matches!(done.get("done"), Some(Value::Bool(true))));
        assert!(done.get("error").is_none(),
                "terminal event carried an error: {}",
                done.to_string_compact());
        assert_eq!(done.get("count").unwrap().as_usize(), Some(10));
    }

    http.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    server.shutdown(Drain::Graceful);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn error_statuses_and_backpressure() {
    let art = synth("errors");
    let server = tiny_server(art.clone(), None);
    let http = HttpServer::start(server.clone(), http_cfg()).unwrap();
    let addr = http.local_addr();

    let r = roundtrip(addr, "POST", "/v1/score", "{not json");
    assert_eq!(r.status, 400);
    let v = r.json();
    assert_eq!(v.get("error").unwrap().get("type").unwrap().as_str(),
               Some("bad_request"));

    let r = roundtrip(addr, "POST", "/v1/completions",
                      "{\"max_new\": 4}");
    assert_eq!(r.status, 400, "missing prompt must 400");

    let r = roundtrip(addr, "POST", "/v1/completions",
                      &completion_body(&[], 4, 0.0, 0, false));
    assert_eq!(r.status, 400, "empty prompt must 400");
    assert_eq!(r.json().get("error").unwrap().get("type").unwrap()
                   .as_str(),
               Some("empty"));

    // 16 prompt tokens + 32 new needs 47 positions in a 32-wide window
    let long: Vec<i32> = (0..16).collect();
    let r = roundtrip(addr, "POST", "/v1/completions",
                      &completion_body(&long, 32, 0.0, 0, false));
    assert_eq!(r.status, 400, "over-long request must 400: {}", r.body);
    assert_eq!(r.json().get("error").unwrap().get("type").unwrap()
                   .as_str(),
               Some("too_long"));

    let r = roundtrip(addr, "GET", "/v1/nope", "");
    assert_eq!(r.status, 404);

    // a zero queue-depth listener sheds every completion with 429
    let shed = HttpServer::start(server.clone(), HttpConfig {
        addr: "127.0.0.1:0".to_string(),
        max_queue_depth: 0,
        retry_after_secs: 7,
        ..HttpConfig::default()
    }).unwrap();
    let r = roundtrip(shed.local_addr(), "POST", "/v1/completions",
                      &completion_body(&[1, 2], 4, 0.0, 0, false));
    assert_eq!(r.status, 429);
    assert_eq!(r.header("retry-after"), Some("7"));
    assert_eq!(r.json().get("error").unwrap().get("type").unwrap()
                   .as_str(),
               Some("backpressure"));
    shed.shutdown();

    http.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    let m = server.shutdown(Drain::Graceful);
    assert!(m.counter("http_4xx") >= 5);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn metrics_render_as_prometheus_text() {
    let art = synth("metrics");
    let server = tiny_server(art.clone(), None);
    let http = HttpServer::start(server.clone(), http_cfg()).unwrap();
    let addr = http.local_addr();

    // traffic first, so counters/gauges/latencies all have samples
    let r = roundtrip(addr, "POST", "/v1/score",
                      "{\"tokens\": [3, 1, 4, 1, 5]}");
    assert_eq!(r.status, 200);
    let r = roundtrip(addr, "POST", "/v1/completions",
                      &completion_body(&[2, 3], 4, 0.0, 0, false));
    assert_eq!(r.status, 200);

    let m = roundtrip(addr, "GET", "/metrics", "");
    assert_eq!(m.status, 200);
    assert!(m.header("content-type").unwrap()
                .starts_with("text/plain"));
    let mut samples = 0;
    for line in m.body.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // every sample line is `name[{labels}] value`
        let (name, value) = line.rsplit_once(' ')
            .unwrap_or_else(|| panic!("unparseable line {line:?}"));
        assert!(name.starts_with("latentllm_"),
                "unprefixed metric {line:?}");
        assert!(value.parse::<f64>().is_ok(),
                "non-numeric value in {line:?}");
        samples += 1;
    }
    assert!(samples >= 5, "suspiciously few samples:\n{}", m.body);
    for want in ["latentllm_requests_total", "latentllm_http_requests_total",
                 "latentllm_gen_queue_depth",
                 // latencies render as native Prometheus histograms
                 "latentllm_request_us_bucket{le=",
                 "latentllm_request_us_bucket{le=\"+Inf\"}",
                 "latentllm_request_us_sum", "latentllm_request_us_count",
                 "# TYPE latentllm_request_us histogram"] {
        assert!(m.body.contains(want), "missing {want}:\n{}", m.body);
    }

    http.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    server.shutdown(Drain::Graceful);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn replies_carry_timings_and_debug_requests_serves_span_chains() {
    let art = synth("traces");
    let server = tiny_server(art.clone(), None);
    let http = HttpServer::start(server.clone(), http_cfg()).unwrap();
    let addr = http.local_addr();

    let score = roundtrip(addr, "POST", "/v1/score",
                          "{\"tokens\": [2, 7, 1, 8]}");
    assert_eq!(score.status, 200, "score body: {}", score.body);
    let t = score.json().get("timings").cloned()
        .expect("score reply carries a timings object");
    assert!(t.get("total_us").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(t.get("preemptions").unwrap().as_usize(), Some(0));

    let comp = roundtrip(addr, "POST", "/v1/completions",
                         &completion_body(&[3, 1, 4], 6, 0.0, 0, false));
    assert_eq!(comp.status, 200, "completion body: {}", comp.body);
    let t = comp.json().get("timings").cloned()
        .expect("completion reply carries a timings object");
    assert_eq!(t.get("tokens").unwrap().as_usize(), Some(6),
               "timings.tokens must equal the tokens delivered");
    assert!(t.get("decode_us").unwrap().as_f64().is_some());

    // the streamed terminal event carries the same timings object
    let reply = roundtrip(addr, "POST", "/v1/completions",
                          &completion_body(&[3, 1, 4], 6, 0.0, 0, true));
    let events = reply.events();
    let done = json::parse(&events[events.len() - 2]).unwrap();
    assert_eq!(done.get("timings").unwrap().get("tokens").unwrap()
                   .as_usize(),
               Some(6));

    // completed traces land in the debug ring, newest first, with the
    // full span chain
    let d = roundtrip(addr, "GET", "/debug/requests?n=2", "");
    assert_eq!(d.status, 200);
    let v = d.json();
    assert_eq!(v.get("count").unwrap().as_usize(), Some(2));
    let reqs = v.get("requests").unwrap().as_arr().unwrap();
    let newest = &reqs[0];
    assert_eq!(newest.get("kind").unwrap().as_str(), Some("generate"));
    assert_eq!(newest.get("failed"), Some(&Value::Bool(false)));
    let names: Vec<&str> = newest.get("events").unwrap().as_arr()
        .unwrap().iter()
        .map(|e| e.get("event").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(names.first(), Some(&"queued"));
    assert!(names.contains(&"admitted"), "span chain: {names:?}");
    assert!(names.contains(&"step"), "span chain: {names:?}");
    assert_eq!(names.last(), Some(&"retired"));

    // the ring holds all three requests even when the query asks for
    // fewer; an uncapped query returns them all
    let all = roundtrip(addr, "GET", "/debug/requests?n=100", "");
    assert!(all.json().get("count").unwrap().as_usize().unwrap() >= 3);

    http.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    server.shutdown(Drain::Graceful);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn graceful_drain_loses_no_inflight_generates() {
    let art = synth("drain");
    // continuous batching so the two streams interleave on one worker
    let server = tiny_server(art.clone(), Some(SchedulerConfig {
        max_live: 4, block_tokens: 2, prefill_chunk: 8, fused: true,
    }));
    let http = HttpServer::start(server.clone(), HttpConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 4, // streams + the shutdown request, concurrently
        ..HttpConfig::default()
    }).unwrap();
    let addr = http.local_addr();

    // open two streaming completions, then request shutdown while the
    // decode loop is still emitting tokens
    let mut streams = Vec::new();
    for i in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let body = completion_body(&[1 + i, 2, 3], 16, 0.0, i as u64,
                                   true);
        write!(s, "POST /v1/completions HTTP/1.1\r\nHost: t\r\n\
                   Connection: close\r\nContent-Length: {}\r\n\r\n{body}",
               body.len()).unwrap();
        streams.push(s);
    }
    std::thread::sleep(Duration::from_millis(50));
    let r = roundtrip(addr, "POST", "/admin/shutdown", "");
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("status").unwrap().as_str(),
               Some("draining"));
    assert!(http.shutdown_requested());

    // both in-flight streams must still complete with every token
    for mut s in streams {
        let mut raw = Vec::new();
        s.read_to_end(&mut raw).expect("stream read");
        let reply = parse_reply(&raw);
        assert_eq!(reply.status, 200);
        let events = reply.events();
        assert_eq!(events.last().map(|e| e.as_str()), Some("[DONE]"));
        let toks = events.iter()
            .filter(|e| e.contains("\"token\""))
            .count();
        assert_eq!(toks, 16, "drained stream lost tokens: {:?}", events);
        let done = json::parse(&events[events.len() - 2]).unwrap();
        assert!(done.get("error").is_none(),
                "in-flight generate failed during drain: {}",
                done.to_string_compact());
        assert_eq!(done.get("count").unwrap().as_usize(), Some(16));
    }

    http.wait(); // returns immediately: shutdown already requested
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("gen_requests"), 2);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn drain_now_answers_every_queued_request() {
    let art = synth("now");
    let server = tiny_server(art.clone(), None);
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    let mut handles = Vec::new();
    for i in 0..6 {
        handles.push(server.submit_generate(GenerateParams {
            prompt: vec![1 + i, 2, 3],
            max_new: 12,
            temperature: 0.0,
            seed: i as u64,
        }).unwrap());
    }
    server.shutdown(Drain::Now);
    let mut ok = 0;
    let mut rejected = 0;
    for h in handles {
        let resp = h.recv_timeout(Duration::from_secs(60))
            .expect("every handle answers even under Drain::Now");
        match resp.result {
            Ok(_) => ok += 1,
            Err(ServeError::Rejected { .. }) => rejected += 1,
            Err(e) => panic!("unexpected error under Drain::Now: {e}"),
        }
    }
    assert_eq!(ok + rejected, 6);
    assert!(rejected >= 1,
            "immediate hard stop should shed at least one queued \
             request (ok={ok})");
    std::fs::remove_dir_all(&art).ok();
}
