//! Integration tests over the real artifacts: program execution on the
//! engine's backend (RefBackend by default, PJRT with `--features pjrt`
//! and `LATENTLLM_BACKEND=pjrt`), python↔rust golden cross-checks, and
//! the full compress→score loop. All tests skip gracefully when artifacts
//! are absent (CI without `make artifacts`), but `make test` runs them
//! for real. Artifact-free RefBackend coverage lives in refbackend.rs.

use latentllm::compress::pipeline::{compress_model, Method};
use latentllm::data::{CalibSet, Corpus};
use latentllm::eval;
use latentllm::model::config::mini_by_name;
use latentllm::model::Weights;
use latentllm::runtime::Engine;
use latentllm::util::json;

fn artifacts() -> Option<std::path::PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    eprintln!("[integration] artifacts missing — skipping");
    None
}

#[test]
fn base_perplexity_matches_python() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    // manifest records the python-side base ppl per corpus
    for model in ["opt-mini-s", "opt-mini-m"] {
        let weights =
            Weights::load(art.join(format!("model_{model}.ltw"))).unwrap();
        let corpus =
            Corpus::load(art.join("corpora.ltw"), "synthwiki", "test")
                .unwrap();
        let got = eval::perplexity(&engine, &format!("score_{model}"),
                                   &weights, &corpus, 8, 128, 24).unwrap();
        let want = engine.manifest()
            .path(&["models", model, "base_ppl", "synthwiki"])
            .and_then(|v| v.as_f64()).unwrap();
        let rel = (got.ppl - want).abs() / want;
        assert!(rel < 0.02, "{model}: rust {} vs python {want}", got.ppl);
    }
}

#[test]
fn rust_compression_matches_python_goldens() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let gold: json::Value = json::parse(
        &std::fs::read_to_string(art.join("goldens.json")).unwrap())
        .unwrap();
    let model = gold.get("model").unwrap().as_str().unwrap().to_string();
    let cfg = mini_by_name(&model).unwrap();
    let weights = Weights::load(art.join(format!("model_{model}.ltw")))
        .unwrap();
    let calib = CalibSet::load(art.join(format!("calib_{model}.ltw")),
                               cfg.n_layers).unwrap();
    let corpus = Corpus::load(art.join("corpora.ltw"), "synthwiki", "test")
        .unwrap();
    let mut ppls = std::collections::BTreeMap::new();
    for e in gold.get("entries").unwrap().as_arr().unwrap() {
        let method = Method::from_name(
            e.get("method").unwrap().as_str().unwrap()).unwrap();
        let ratio = e.get("ratio").unwrap().as_f64().unwrap();
        if ratio != 0.2 {
            continue; // one ratio is enough for the cross-check; speed
        }
        let want = e.get("ppl").unwrap().as_f64().unwrap();
        let (nw, rep) = compress_model(cfg, &weights, &calib, method, ratio,
                                       8, 4).unwrap();
        let got = eval::perplexity(&engine, &format!("score_{model}"), &nw,
                                   &corpus, 8, 128, 24).unwrap();
        let rel = (got.ppl - want).abs() / want;
        // rust and python implement the same math but not bitwise-identical
        // SVDs; ppl agreement within a few percent is the contract.
        assert!(rel < 0.05,
                "{method:?}@{ratio}: rust {} vs python {want}", got.ppl);
        let ach = rep.achieved_ratio();
        assert!((ach - ratio).abs() < 0.05, "{method:?} ratio {ach}");
        ppls.insert(method.name(), got.ppl);
    }
    // the paper's ordering must hold in the rust pipeline too
    assert!(ppls["latentllm"] <= ppls["asvd_rootcov"] * 1.02);
    assert!(ppls["asvd_rootcov"] <= ppls["plain"] * 1.02);
}

#[test]
fn latent_program_matches_dense_reconstruction() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let man = engine.manifest();
    let Some(tag) = man.path(&["latent_demo", "tag"])
        .and_then(|v| v.as_str()) else { return };
    let model = man.path(&["latent_demo", "model"]).unwrap()
        .as_str().unwrap();
    let lat_w = Weights::load(art.join(format!("latent_model_{tag}.ltw")))
        .unwrap();
    let corpus = Corpus::load(art.join("corpora.ltw"), "synthwiki", "test")
        .unwrap();
    let lat = eval::perplexity(&engine, &format!("latent_score_{tag}"),
                               &lat_w, &corpus, 8, 128, 6).unwrap();
    // python recorded latent-vs-reconstructed ppl equality at build time;
    // here we verify the rust-executed latent program agrees with it and
    // sits above the uncompressed baseline.
    let base = man.path(&["models", model, "base_ppl", "synthwiki"])
        .and_then(|v| v.as_f64()).unwrap();
    assert!(lat.ppl.is_finite() && lat.ppl > 0.0);
    assert!(lat.ppl >= base * 0.95,
            "compressed ppl {} should not beat base {base} by much",
            lat.ppl);
    assert!(lat.ppl < base * 3.0,
            "latent program ppl {} looks broken vs base {base}", lat.ppl);
}

#[test]
fn mm_accuracy_matches_python_baseline() {
    let Some(art) = artifacts() else { return };
    let engine = Engine::new(&art).unwrap();
    let weights = Weights::load(art.join("mm_model.ltw")).unwrap();
    let data = latentllm::model::io::read_ltw(art.join("mm_data.ltw"))
        .unwrap();
    let r = eval::evaluate_mm(&engine, "mm_score_llava-mini", &weights,
                              &data, 16).unwrap();
    let want = engine.manifest().path(&["mm", "base_acc", "Avg"])
        .and_then(|v| v.as_f64()).unwrap();
    assert!((r.avg - want).abs() < 0.02,
            "rust {} vs python {want}", r.avg);
    // category orderings from the synthetic design
    // TXT (direct give-away) must be the easiest modality
    assert!(r.by_modality[0] >= r.by_modality[2],
            "TXT {} < NO {}", r.by_modality[0], r.by_modality[2]);
}

#[test]
fn serving_stack_end_to_end() {
    use latentllm::coordinator::batcher::BatcherConfig;
    use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
    use latentllm::coordinator::router::{ModelVariant, Policy, Router};
    use latentllm::coordinator::server::{Drain, ScoreParams, Server,
                                         ServerConfig};
    let Some(art) = artifacts() else { return };
    let model = "opt-mini-s";
    let cfg = mini_by_name(model).unwrap();
    let weights = Weights::load(art.join(format!("model_{model}.ltw")))
        .unwrap();
    let corpus = Corpus::load(art.join("corpora.ltw"), "synthwiki", "test")
        .unwrap();
    let variants = vec![ModelVariant {
        name: "dense".into(),
        score_program: format!("score_{model}"),
        step_program: format!("step_{model}"),
        weights: std::sync::Arc::new(weights),
        cache: KvCacheManager::new(CacheKind::Dense { d: cfg.d },
                                   cfg.n_layers, 2, 32 << 20),
    }];
    let server = Server::start(art.clone(),
                               Router::new(variants, Policy::RoundRobin),
                               ServerConfig {
                                   batcher: BatcherConfig::default(),
                                   policy: Policy::RoundRobin,
                                   program_batch: 8,
                                   seq_len: 128,
                                   workers: 2,
                                   sched: None,
                                   trace: true,
                               })
        .expect("server start");
    let reqs = corpus.calibration(24, 128, 5);
    let rxs: Vec<_> = reqs.into_iter()
        .map(|tokens| server.submit_score(ScoreParams { tokens })
            .expect("submit"))
        .collect();
    let mut got = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(120))
            .expect("response");
        assert!(resp.nll().is_finite());
        got += 1;
    }
    assert_eq!(got, 24);
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("requests"), 24);
    assert!(m.counter("batches") >= 3);
    assert_eq!(m.counter("batch_errors"), 0);
}
