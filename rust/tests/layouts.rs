//! Execution-layout regression suite.
//!
//! Three families of pins:
//!  * a property test that the fused-dequant int8 kernel agrees with the
//!    dequantize-then-f64-matmul reference to 1e-6 relative across random
//!    shapes and chunk widths;
//!  * bit-identity of the default `DenseF64` layout on the decode path —
//!    greedy and sampled, dense and latent programs — against the
//!    original weight set (the typed-dispatch refactor must be invisible
//!    at the default layout);
//!  * end-to-end decode on repacked f32/int8 artifacts: sessions open,
//!    tokens come out in-vocab, and the artifact round-trips its layout
//!    through save/load.

use std::path::PathBuf;

use latentllm::data::synth::write_test_artifacts;
use latentllm::eval::generate::{generate, GenerateOpts};
use latentllm::model::config::MiniConfig;
use latentllm::model::Weights;
use latentllm::prop_assert;
use latentllm::runtime::Engine;
use latentllm::util::prop::{dim, run_cases};
use latentllm::util::rng::Rng;
use latentllm::{Layout, Matrix, PackedMat};

const TINY: MiniConfig = MiniConfig {
    name: "tiny", vocab: 48, d: 16, n_layers: 2, n_heads: 2,
    d_i: 32, max_len: 32,
};
const SEQ: usize = 32;
const BATCH: usize = 8;

fn synth(tag: &str) -> (PathBuf, String) {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_layouts_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let latent_tag = write_test_artifacts(&dir, &TINY, 37).unwrap();
    (dir, latent_tag)
}

fn prompts() -> Vec<Vec<i32>> {
    vec![vec![1, 2, 3], vec![7, 11, 13, 17, 19], vec![40, 2, 40, 2]]
}

fn opts(max_new: usize, temperature: f64) -> GenerateOpts {
    GenerateOpts { max_new, temperature, seed: 5, use_cache: true }
}

#[test]
fn quant_i8_matmul_matches_dequant_reference() {
    // q.apply(x) (fused dequant in the kernel epilogue) must agree with
    // dequantizing to f64 first and running the reference matmul_bt —
    // the two paths share the grid, so only accumulation order differs
    run_cases("quant_i8 == dequant ∘ matmul_bt", 40, 0xA11, |rng, _| {
        let rows = dim(rng, 1, 24);
        let cols = dim(rng, 1, 40);
        let m = dim(rng, 1, 4);
        let chunk = [1usize, 3, 8, 17, 64][rng.below(5)];
        let w = rng.normal_matrix(rows, cols);
        let x = rng.normal_matrix(m, cols);
        let q = PackedMat::quantize_i8(&w, chunk);
        let want = x.matmul_bt(&q.to_matrix());
        let got = q.apply(&x);
        prop_assert!(got.rows() == want.rows() && got.cols() == want.cols(),
                     "shape mismatch");
        let scale = want.data().iter().fold(1.0f64, |a, &v| a.max(v.abs()));
        for (a, b) in got.data().iter().zip(want.data()) {
            prop_assert!((a - b).abs() <= 1e-6 * scale,
                         "{rows}x{cols} chunk={chunk}: {a} vs {b} \
                          (rel tol 1e-6)");
        }
        Ok(())
    });
}

#[test]
fn packed_f32_matmul_matches_f32_reference() {
    // the panel kernel computes in f64 over f32-rounded weights: it must
    // match matmul_bt against the f32-rounded dense operand to rounding
    // noise
    run_cases("packed_f32 == f32-rounded matmul_bt", 25, 0xB22, |rng, _| {
        let rows = dim(rng, 1, 30);
        let cols = dim(rng, 1, 33);
        let m = dim(rng, 1, 3);
        let w = rng.normal_matrix(rows, cols);
        let x = rng.normal_matrix(m, cols);
        let p = PackedMat::pack_f32(&w);
        let wr = Matrix::from_fn(rows, cols, |i, j| w[(i, j)] as f32 as f64);
        let want = x.matmul_bt(&wr);
        let got = p.apply(&x);
        let scale = want.data().iter().fold(1.0f64, |a, &v| a.max(v.abs()));
        for (a, b) in got.data().iter().zip(want.data()) {
            prop_assert!((a - b).abs() <= 1e-9 * scale,
                         "{rows}x{cols}: {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn dense_layout_decode_is_bit_identical() {
    // the refactor's contract: at the default DenseF64 layout the typed
    // dispatch is the *same arithmetic* as the pre-refactor decode, so a
    // repacked (fresh model build, PackedMat path) weight set produces
    // token-for-token identical sequences — greedy and sampled, dense
    // and latent
    let (art, tag) = synth("dense_id");
    let engine = Engine::new(&art).unwrap();
    let cases = [
        (format!("step_{}", TINY.name),
         Weights::load(art.join(format!("model_{}.ltw", TINY.name)))
             .unwrap()),
        (format!("latent_step_{tag}"),
         Weights::load(art.join(format!("latent_model_{tag}.ltw")))
             .unwrap()),
    ];
    for (program, weights) in &cases {
        assert_eq!(weights.layout(), Layout::DenseF64,
                   "synthesized artifacts default to the dense layout");
        let re = weights.repack(Layout::DenseF64, 64).unwrap();
        assert_ne!(re.cache_id(), weights.cache_id(),
                   "repack must force a fresh model build");
        for temperature in [0.0, 0.8] {
            let a = generate(&engine, program, weights, &prompts(), BATCH,
                             SEQ, TINY.vocab, &opts(10, temperature))
                .unwrap();
            let b = generate(&engine, program, &re, &prompts(), BATCH,
                             SEQ, TINY.vocab, &opts(10, temperature))
                .unwrap();
            assert_eq!(a.sequences, b.sequences,
                       "{program} t={temperature}: dense layout diverged");
        }
    }
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn repacked_artifacts_decode_end_to_end() {
    // f32 and int8 artifacts: save → load keeps the layout tag, decode
    // sessions run, and every emitted token is in-vocab
    let (art, tag) = synth("packed_e2e");
    let engine = Engine::new(&art).unwrap();
    let cases = [
        (format!("step_{}", TINY.name),
         Weights::load(art.join(format!("model_{}.ltw", TINY.name)))
             .unwrap()),
        (format!("latent_step_{tag}"),
         Weights::load(art.join(format!("latent_model_{tag}.ltw")))
             .unwrap()),
    ];
    for (program, weights) in &cases {
        for layout in [Layout::PackedF32, Layout::QuantI8] {
            let rp = weights.repack(layout, 32).unwrap();
            let p = art.join(format!("repacked_{}.ltw", layout.name()));
            rp.save(&p).unwrap();
            let loaded = Weights::load(&p).unwrap();
            assert_eq!(loaded.layout(), layout,
                       "layout tag must survive the round-trip");
            assert_eq!(loaded.map(), rp.map());
            let res = generate(&engine, program, &loaded, &prompts(),
                               BATCH, SEQ, TINY.vocab, &opts(8, 0.0))
                .unwrap();
            assert!(res.tokens_generated > 0,
                    "{program} {}: no tokens emitted", layout.name());
            for s in &res.sequences {
                assert!(s.iter().all(|&t| (0..TINY.vocab as i32)
                            .contains(&t)),
                        "{program} {}: token out of vocab", layout.name());
            }
        }
    }
    std::fs::remove_dir_all(&art).ok();
}
