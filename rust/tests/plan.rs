//! Plan-API regression suite (artifact-free).
//!
//! The heart is `shim_matches_reference_bitwise`: a verbatim copy of the
//! pre-refactor enum pipeline (`reference_compress`) is pinned against
//! `compress_model` — now a `Method::plan()` shim over
//! `plan::compress_plan` — with byte-for-byte tensor equality, so the
//! stage decomposition can never drift arithmetically from the §5
//! protocol. The rest covers plan TOML files on disk, custom stage
//! registration, and the mixed per-layer-ratio + sparse/quant scenarios
//! the Method enum could not express.

use latentllm::compress::asvd::{self, AsvdOpts};
use latentllm::compress::joint_qk::{self, JointQkOpts};
use latentllm::compress::joint_ud::{self, JointUdOpts};
use latentllm::compress::joint_vo::{self, JointVoOpts};
use latentllm::compress::junction::Junction;
use latentllm::compress::pipeline::{compress_model, tests_support, Method,
                                    TABLE2_METHODS};
use latentllm::compress::plan::{compress_plan, compress_plan_on,
                                CompressionPlan, Compressor, LayerCtx,
                                LayerOut, PostOp, Registry};
use latentllm::compress::rank;
use latentllm::data::CalibSet;
use latentllm::model::config::OPT_MINI_S;
use latentllm::model::{MiniConfig, Weights};
use latentllm::util::pool::Pool;
use latentllm::Matrix;

// ---------------------------------------------------------------------------
// verbatim copy of the pre-refactor §5 pipeline (serial)

fn reference_compress(cfg: &MiniConfig, weights: &Weights, calib: &CalibSet,
                      method: Method, ratio: f64, qk_iters: usize,
                      ud_iters: usize) -> Weights {
    let keep = 1.0 - ratio;
    let pk = method.precond();
    let latent = method.is_latent();
    let junction = if latent { Junction::BlockId } else { Junction::Left };
    let (d, dh, h, di) = (cfg.d, cfg.d_h(), cfg.n_heads, cfg.d_i);
    let mut out = weights.clone();

    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        let x_attn = calib.x(i, "attn_x");
        let x_o = calib.x(i, "o_x");
        let x_mlp = calib.x(i, "mlp_x");

        let wq = weights.matrix(&format!("{p}attn.wq")).unwrap();
        let wk = weights.matrix(&format!("{p}attn.wk")).unwrap();
        let wv = weights.matrix(&format!("{p}attn.wv")).unwrap();
        let wo = weights.matrix(&format!("{p}attn.wo")).unwrap();
        let bq = weights.bias(&format!("{p}attn.bq")).unwrap();
        let bk = weights.bias(&format!("{p}attn.bk")).unwrap();
        let bv = weights.bias(&format!("{p}attn.bv")).unwrap();
        let bo = weights.bias(&format!("{p}attn.bo")).unwrap();
        let wu = weights.matrix(&format!("{p}mlp.wu")).unwrap();
        let wd = weights.matrix(&format!("{p}mlp.wd")).unwrap();
        let bu = weights.bias(&format!("{p}mlp.bu")).unwrap();
        let bd = weights.bias(&format!("{p}mlp.bd")).unwrap();

        if latent {
            // ---- joint QK (§4.1, Alg 1)
            let r_qk = rank::joint_qk_rank(d, dh, h, h, keep, true);
            let jq = joint_qk::compress(&wq, &wk, h, dh, r_qk, r_qk,
                                        &JointQkOpts {
                                            kind: pk, n_iter: qk_iters,
                                            x: Some(x_attn),
                                            bq: Some(&bq), bk: Some(&bk),
                                            ..Default::default()
                                        });
            out.set_matrix(&format!("{p}attn.wq"), &jq.wq_hat);
            out.set_matrix(&format!("{p}attn.wk"), &jq.wk_hat);
            out.set_bias(&format!("{p}attn.bq"), &jq.bq_bias.unwrap());
            out.set_bias(&format!("{p}attn.bk"), &jq.bk_bias.unwrap());

            // ---- V / O
            if method == Method::LatentLlmJointVo {
                let r_vo = rank::local_rank(d, d, keep, true);
                let jv = joint_vo::compress(&wv, &wo, h, dh, r_vo, r_vo,
                                            &JointVoOpts {
                                                kind: pk, n_iter: ud_iters,
                                                x: Some(x_attn),
                                                bv: Some(&bv),
                                                bo: Some(&bo),
                                                ..Default::default()
                                            });
                out.set_matrix(&format!("{p}attn.wv"), &jv.wv_hat);
                out.set_matrix(&format!("{p}attn.wo"), &jv.wo_hat);
                out.set_bias(&format!("{p}attn.bo"), &jv.bo_bias.unwrap());
            } else {
                // paper default: split V/O, root-cov + block identity
                let r_v = rank::local_rank(d, d, keep, true);
                let rv = asvd::compress(&wv, r_v, &AsvdOpts {
                    kind: pk, junction, x: Some(x_attn), bias: Some(&bv),
                    ..Default::default()
                });
                let r_o = rank::local_rank(d, d, keep, true);
                let ro = asvd::compress(&wo, r_o, &AsvdOpts {
                    kind: pk, junction, x: Some(x_o), bias: Some(&bo),
                    ..Default::default()
                });
                out.set_matrix(&format!("{p}attn.wv"), &rv.w_hat);
                out.set_bias(&format!("{p}attn.bv"), &rv.bias.unwrap());
                out.set_matrix(&format!("{p}attn.wo"), &ro.w_hat);
                out.set_bias(&format!("{p}attn.bo"), &ro.bias.unwrap());
            }

            // ---- joint UD (§4.3)
            let r_u = rank::local_rank(di, d, keep, true);
            let r_d = rank::local_rank(d, di, keep, true);
            let ud = joint_ud::compress(&wu, &bu, &wd, &bd, x_mlp, r_u,
                                        r_d,
                                        &JointUdOpts {
                                            n_iter: ud_iters,
                                            junction,
                                            ..Default::default()
                                        });
            out.set_matrix(&format!("{p}mlp.wu"), &ud.wu_hat);
            out.set_bias(&format!("{p}mlp.bu"), &ud.bu);
            out.set_matrix(&format!("{p}mlp.wd"), &ud.wd_hat);
            out.set_bias(&format!("{p}mlp.bd"), &ud.bd);
        } else {
            // local compression of each of the six linears
            let jobs: [(&str, &Matrix, &[f64], &Matrix); 5] = [
                ("attn.wq", &wq, &bq, x_attn),
                ("attn.wk", &wk, &bk, x_attn),
                ("attn.wv", &wv, &bv, x_attn),
                ("attn.wo", &wo, &bo, x_o),
                ("mlp.wu", &wu, &bu, x_mlp),
            ];
            for (name, w, b, x) in jobs {
                let r = rank::local_rank(w.rows(), w.cols(), keep, false);
                let res = asvd::compress(w, r, &AsvdOpts {
                    kind: pk, junction, x: Some(x), bias: Some(b),
                    ..Default::default()
                });
                out.set_matrix(&format!("{p}{name}"), &res.w_hat);
                let bname = format!("{p}{}", name.replace('w', "b"));
                out.set_bias(&bname, &res.bias.unwrap());
            }
            // wd sees σ(Wu_orig x + bu)
            let mut z = wu.matmul(x_mlp);
            for r in 0..z.rows() {
                let bi = bu[r];
                for v in z.row_mut(r) {
                    *v = (*v + bi).max(0.0);
                }
            }
            let r = rank::local_rank(d, di, keep, false);
            let res = asvd::compress(&wd, r, &AsvdOpts {
                kind: pk, junction, x: Some(&z), bias: Some(&bd),
                ..Default::default()
            });
            out.set_matrix(&format!("{p}mlp.wd"), &res.w_hat);
            out.set_bias(&format!("{p}mlp.bd"), &res.bias.unwrap());
        }
    }
    out
}

fn assert_bitwise_equal(a: &Weights, b: &Weights, tag: &str) {
    assert_eq!(a.names().count(), b.names().count(), "{tag}: name sets");
    for name in a.names() {
        let ta = a.tensor(name).unwrap().as_f32().unwrap();
        let tb = b.tensor(name).unwrap().as_f32().unwrap();
        assert_eq!(ta.len(), tb.len(), "{tag}: {name} length");
        assert!(ta.iter().zip(tb.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "{tag}: {name} diverged from the pre-refactor pipeline");
    }
}

fn setup() -> (MiniConfig, Weights, CalibSet) {
    let cfg = OPT_MINI_S;
    let w = tests_support::random_weights(&cfg, 2024);
    let cal = CalibSet::synthetic(cfg.n_layers, cfg.d, 192, 11);
    (cfg, w, cal)
}

#[test]
fn shim_matches_reference_bitwise() {
    let (cfg, w, cal) = setup();
    // acceptance bar: every TABLE2 method at ratio 0.5, plus the joint-VO
    // ablation arm, plus a second ratio for the two §5 headline methods
    let mut cases: Vec<(Method, f64)> =
        TABLE2_METHODS.iter().map(|&m| (m, 0.5)).collect();
    cases.push((Method::LatentLlmJointVo, 0.5));
    cases.push((Method::LatentLlm, 0.25));
    cases.push((Method::AsvdRootCov, 0.25));
    for (method, ratio) in cases {
        let want = reference_compress(&cfg, &w, &cal, method, ratio, 2, 1);
        let (got, rep) = compress_model(&cfg, &w, &cal, method, ratio, 2, 1)
            .unwrap();
        assert_bitwise_equal(&want, &got,
                             &format!("{method:?}@{ratio}"));
        assert!((rep.achieved_ratio() - ratio).abs() < 0.06,
                "{method:?}@{ratio}: achieved {}", rep.achieved_ratio());
    }
}

#[test]
fn plan_file_round_trips_through_disk() {
    let plan = Method::LatentLlm.plan()
        .named("disk-trip")
        .with_ratio(0.35)
        .with_layer_ratios(vec![0.2, 0.45])
        .with_iters(3, 2)
        .with_rank("attn.qk", 40)
        .with_post(PostOp::Sparse { keep_frac: 0.03, n_iter: 12 })
        .with_post(PostOp::Quant { bits: 6, chunk: 32 });
    let path = std::env::temp_dir().join(format!(
        "latentllm_plan_{}.toml", std::process::id()));
    std::fs::write(&path, plan.to_toml()).unwrap();
    let loaded = CompressionPlan::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(plan, loaded);
}

#[test]
fn example_plans_parse_and_resolve() {
    // the same files CI dry-runs; resolving them validates stage names,
    // ratio bounds, and rank overrides against a real config
    let reg = Registry::builtin();
    for file in ["plan_latentllm.toml", "plan_mixed.toml"] {
        let path = ["examples", "../examples"].iter()
            .map(|d| std::path::Path::new(d).join(file))
            .find(|p| p.exists())
            .unwrap_or_else(|| panic!("{file} not found from {:?}",
                                      std::env::current_dir()));
        let plan = CompressionPlan::load(&path).unwrap();
        let layers = plan.resolve(&reg, &OPT_MINI_S).unwrap();
        assert_eq!(layers.len(), OPT_MINI_S.n_layers);
        assert!(layers.iter().all(|l| !l.modules.is_empty()));
    }
}

#[test]
fn mixed_ratio_sparse_plan_end_to_end() {
    let (cfg, w, cal) = setup();
    let base = Method::LatentLlm.plan()
        .with_layer_ratios(vec![0.2, 0.5])
        .with_iters(2, 1);
    let sparse = base.clone()
        .with_post(PostOp::Sparse { keep_frac: 0.02, n_iter: 10 });
    let (nw_base, rep_base) = compress_plan(&cfg, &w, &cal, &base).unwrap();
    let (nw, rep) = compress_plan(&cfg, &w, &cal, &sparse).unwrap();
    // per-layer schedule took effect
    assert!(rep.layers[0].qk_rank > rep.layers[1].qk_rank);
    // the sparse correction adds params and moves the weights
    assert!(rep.new_linear_params > rep_base.new_linear_params,
            "sparse post-stage must count its κ entries");
    let a = nw.matrix("layers.0.attn.wv").unwrap();
    let b = nw_base.matrix("layers.0.attn.wv").unwrap();
    assert!(a.max_abs_diff(&b) > 0.0,
            "sparse correction should perturb the low-rank Ŵ");
    for name in nw.names() {
        let t = nw.tensor(name).unwrap();
        if let Ok(data) = t.as_f32() {
            assert!(data.iter().all(|v| v.is_finite()),
                    "{name} has non-finite values");
        }
    }
}

#[test]
fn quant_post_stage_quantizes_weights() {
    let (cfg, w, cal) = setup();
    let plan = Method::AsvdRootCov.plan()
        .with_ratio(0.3)
        .with_iters(2, 1)
        .with_post(PostOp::Quant { bits: 4, chunk: 64 });
    let (nw, rep) = compress_plan(&cfg, &w, &cal, &plan).unwrap();
    assert!((rep.achieved_ratio() - 0.3).abs() < 0.06);
    // 4-bit chunks: each 64-value chunk holds at most 16 distinct levels
    let m = nw.matrix("layers.0.attn.wq").unwrap();
    let chunk: Vec<i64> = m.data()[..64].iter()
        .map(|v| (v * 1e9).round() as i64).collect();
    let uniq: std::collections::BTreeSet<i64> =
        chunk.into_iter().collect();
    assert!(uniq.len() <= 16, "got {} distinct levels", uniq.len());
}

#[test]
fn quant8_post_stage_emits_native_int8_tensors() {
    use latentllm::model::io::Tensor;
    let (cfg, w, cal) = setup();
    let plan = Method::AsvdRootCov.plan()
        .with_ratio(0.3)
        .with_iters(2, 1)
        .with_post(PostOp::Quant { bits: 8, chunk: 64 });
    let (nw, _) = compress_plan(&cfg, &w, &cal, &plan).unwrap();
    // the 8-bit post-stage stores i8 codes + affine params, not a
    // dequantized f64 simulation
    let t = nw.tensor("layers.0.attn.wq").unwrap();
    assert!(matches!(t, Tensor::QuantI8 { .. }),
            "8-bit quant must emit the execution layout");
    // biases and untouched tensors stay f32
    assert!(matches!(nw.tensor("layers.0.attn.bq").unwrap(),
                     Tensor::F32 { .. }));
    assert!(matches!(nw.tensor("tok_emb").unwrap(), Tensor::F32 { .. }));
    // the dense view dequantizes onto the same Eq 242 grid the f64
    // simulation uses (f32 affine params ⇒ ~1e-6 relative agreement)
    let m = nw.matrix("layers.0.attn.wq").unwrap();
    let scale = m.data().iter().fold(0.0f64, |a, &v| a.max(v.abs()));
    for s in m.data().chunks(64) {
        let lo = s.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if hi - lo > 1e-12 {
            let step = (hi - lo) / 255.0;
            for &v in s {
                let code = (v - lo) / step;
                assert!((code - code.round()).abs() < 1e-3 * scale.max(1.0),
                        "dequantized value off the 8-bit grid");
            }
        }
    }
}

/// A custom stage registered at runtime: leaves the MLP uncompressed.
struct MlpKeep;

impl Compressor for MlpKeep {
    fn name(&self) -> &'static str {
        "mlp_keep"
    }

    fn compress(&self, ctx: &LayerCtx) -> anyhow::Result<LayerOut> {
        let p = ctx.prefix();
        let mut out = LayerOut::new(ctx.layer);
        // re-emit the original tensors; params = full dense count
        for (wname, bname) in [("mlp.wu", "mlp.bu"), ("mlp.wd", "mlp.bd")] {
            let w = ctx.matrix(wname)?;
            out.rep.params += w.rows() * w.cols();
            out.mats.push((format!("{p}{wname}"), w));
            out.biases.push((format!("{p}{bname}"), ctx.bias(bname)?));
        }
        Ok(out)
    }
}

#[test]
fn custom_compressor_via_registry() {
    let (cfg, w, cal) = setup();
    let mut reg = Registry::builtin();
    reg.register(std::sync::Arc::new(MlpKeep));
    let mut plan = Method::LatentLlm.plan().with_ratio(0.4)
        .with_iters(2, 1);
    plan.mlp = "mlp_keep".into();
    let (nw, rep) = compress_plan_on(&Pool::new(2), &reg, &cfg, &w, &cal,
                                     &plan, None).unwrap();
    // the MLP survived bit-identically; attention was compressed
    for name in ["layers.0.mlp.wu", "layers.1.mlp.wd"] {
        let a = nw.tensor(name).unwrap().as_f32().unwrap();
        let b = w.tensor(name).unwrap().as_f32().unwrap();
        assert!(a.iter().zip(b.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "{name} should be untouched by mlp_keep");
    }
    let wq_new = nw.matrix("layers.0.attn.wq").unwrap();
    let wq_old = w.matrix("layers.0.attn.wq").unwrap();
    assert!(wq_new.max_abs_diff(&wq_old) > 0.0);
    // dense MLP params + compressed attention params
    let dense_mlp = 2 * cfg.d * cfg.d_i * cfg.n_layers;
    assert!(rep.new_linear_params > dense_mlp);
    // an unregistered stage name fails with a useful error
    let plain_reg = Registry::builtin();
    let err = compress_plan_on(&Pool::new(1), &plain_reg, &cfg, &w, &cal,
                               &plan, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("mlp_keep"),
            "error should name the missing stage: {msg}");
}
