//! Randomized property suites over the numeric substrate and the
//! compression algorithms (the in-repo prop harness; seeds are reported on
//! failure for exact reproduction).

use latentllm::compress::asvd::{self, AsvdOpts};
use latentllm::compress::junction::Junction;
use latentllm::compress::precond::Precond;
use latentllm::compress::{joint_qk, rank};
use latentllm::prop_assert;
use latentllm::tensor::{eigh, pinv, pinv_psd, sqrt_and_invsqrt_psd,
                        svd_truncated};
use latentllm::util::prop::{dim, run_cases};
use latentllm::Matrix;

#[test]
fn prop_svd_truncation_is_eckart_young() {
    run_cases("svd-eckart-young", 25, 0xA1, |rng, _| {
        let m = dim(rng, 5, 40);
        let n = dim(rng, 5, 40);
        let a = rng.normal_matrix(m, n);
        let k = m.min(n);
        let full = latentllm::tensor::svd(&a);
        let r = 1 + rng.below(k);
        let t = svd_truncated(&a, r);
        let err = a.sub(&t.reconstruct()).frob2();
        let tail: f64 = full.s[r.min(k)..].iter().map(|s| s * s).sum();
        prop_assert!((err - tail).abs() < 1e-6 * (1.0 + tail),
                     "m={m} n={n} r={r}: err {err} tail {tail}");
        Ok(())
    });
}

#[test]
fn prop_eigh_reconstruction_and_orthogonality() {
    run_cases("eigh-reconstruct", 20, 0xA2, |rng, _| {
        let n = dim(rng, 5, 64);
        let extra = dim(rng, 0, 8);
        let g = rng.normal_matrix(n, n + extra);
        let a = g.matmul_bt(&g);
        let (w, v) = eigh(&a);
        let mut s = Matrix::zeros(n, n);
        for i in 0..n {
            s[(i, i)] = w[i];
            prop_assert!(w[i] >= -1e-8, "n={n}: negative eig {}", w[i]);
        }
        let rec = v.matmul(&s).matmul_bt(&v);
        prop_assert!(rec.max_abs_diff(&a) < 1e-7 * n as f64,
                     "n={n}: reconstruction");
        let vtv = v.matmul_at(&v);
        prop_assert!(vtv.max_abs_diff(&Matrix::eye(n)) < 1e-8,
                     "n={n}: orthogonality");
        Ok(())
    });
}

#[test]
fn prop_sqrt_pair_consistency() {
    run_cases("sqrt-invsqrt", 15, 0xA3, |rng, _| {
        let n = dim(rng, 4, 48);
        let g = rng.normal_matrix(n, n + 4);
        let c = g.matmul_bt(&g);
        let (p, p_inv) = sqrt_and_invsqrt_psd(&c);
        prop_assert!(p.matmul(&p).max_abs_diff(&c) < 1e-6 * n as f64,
                     "n={n}: P² ≠ C");
        prop_assert!(p.matmul(&p_inv).max_abs_diff(&Matrix::eye(n))
                     < 1e-6 * n as f64, "n={n}: P·P⁻¹ ≠ I");
        let pp = pinv_psd(&c);
        prop_assert!(c.matmul(&pp).matmul(&c).max_abs_diff(&c)
                     < 1e-6 * n as f64, "n={n}: C C⁺ C ≠ C");
        Ok(())
    });
}

#[test]
fn prop_pinv_moore_penrose_rectangular() {
    run_cases("pinv-mp", 15, 0xA4, |rng, _| {
        let m = dim(rng, 3, 24);
        let n = dim(rng, 3, 24);
        let a = rng.normal_matrix(m, n);
        let p = pinv(&a);
        prop_assert!(a.matmul(&p).matmul(&a).max_abs_diff(&a) < 1e-8,
                     "{m}x{n}: A A⁺ A");
        prop_assert!(p.matmul(&a).matmul(&p).max_abs_diff(&p) < 1e-8,
                     "{m}x{n}: A⁺ A A⁺");
        Ok(())
    });
}

#[test]
fn prop_junction_loss_invariance() {
    run_cases("junction-invariance", 20, 0xA5, |rng, _| {
        let d_out = dim(rng, 4, 24);
        let d_in = dim(rng, 4, 24);
        let r = 1 + rng.below(d_out.min(d_in));
        let w = rng.normal_matrix(d_out, d_in);
        let mut w_hats = Vec::new();
        for junction in [Junction::Left, Junction::Right, Junction::Sym,
                         Junction::BlockId] {
            let res = asvd::compress(&w, r, &AsvdOpts {
                kind: Precond::Identity, junction, ..Default::default() });
            w_hats.push(res.w_hat);
        }
        for other in &w_hats[1..] {
            prop_assert!(w_hats[0].max_abs_diff(other) < 1e-7,
                         "junction changed Ŵ ({d_out}x{d_in} r={r})");
        }
        Ok(())
    });
}

#[test]
fn prop_rootcov_never_loses() {
    run_cases("rootcov-optimal", 12, 0xA6, |rng, _| {
        let d = dim(rng, 6, 20);
        let dof = 2 * d;
        let sigma = latentllm::util::rng::decaying_covariance(
            d, 0.5 + 0.45 * rng.uniform());
        let c = latentllm::util::rng::wishart(rng, &sigma, dof);
        let rows = dim(rng, 4, 16);
        let w = rng.normal_matrix(rows, d);
        let r = 1 + rng.below(w.rows().min(d) - 1).max(1);
        let mut best_other = f64::INFINITY;
        let mut root = f64::NAN;
        for kind in latentllm::compress::precond::ALL {
            let res = asvd::compress_with_cov(
                &w, r, &c, &vec![0.0; d],
                &AsvdOpts { kind, junction: Junction::Left,
                            ..Default::default() });
            if kind == Precond::RootCov {
                root = res.loss;
            } else {
                best_other = best_other.min(res.loss);
            }
        }
        prop_assert!(root <= best_other * (1.0 + 1e-9),
                     "rootcov {root} vs best-other {best_other} (d={d})");
        Ok(())
    });
}

#[test]
fn prop_joint_qk_never_increases_loss_over_iterations() {
    run_cases("alg1-monotone", 10, 0xA7, |rng, _| {
        let h = 1 + rng.below(4);
        let dh = 2 + rng.below(6);
        let d = h * dh * (1 + rng.below(2));
        let wq = rng.normal_matrix(h * dh, d);
        let wk = rng.normal_matrix(h * dh, d);
        let r = 1 + rng.below(d);
        let res = joint_qk::compress(
            &wq, &wk, h, dh, r, r,
            &joint_qk::JointQkOpts { kind: Precond::Identity, n_iter: 5,
                                     ..Default::default() });
        // absolute tolerance floor: at (near-)full rank the loss is ~0 and
        // pure fp noise, so compare with an epsilon scaled by the energy
        let scale: f64 = 1e-9 * (1.0 + wq.frob2() * wk.frob2());
        for w in res.losses.windows(2) {
            prop_assert!(w[1] <= w[0] * (1.0 + 1e-9) + scale,
                         "h={h} dh={dh} d={d} r={r}: {:?}", res.losses);
        }
        prop_assert!(res.losses[0].is_finite(), "finite losses");
        Ok(())
    });
}

#[test]
fn prop_rank_accounting_consistent() {
    run_cases("rank-accounting", 30, 0xA8, |rng, _| {
        let d = 8 * (1 + rng.below(24));
        let h = [2usize, 4, 8][rng.below(3)];
        if d % h != 0 {
            return Ok(());
        }
        let dh = d / h;
        let keep = 0.3 + 0.65 * rng.uniform();
        let r = rank::joint_qk_rank(d, dh, h, h, keep, true);
        let p = rank::joint_qk_params(d, dh, h, h, r, r, true);
        let orig = 2 * d * d;
        prop_assert!(p <= orig, "params {p} exceed original {orig}");
        prop_assert!(r >= 1 && r <= d, "rank {r} out of range");
        Ok(())
    });
}

#[test]
fn prop_ltw_roundtrip_random() {
    use latentllm::model::io::{parse_ltw, write_ltw, Tensor, TensorMap};
    run_cases("ltw-roundtrip", 15, 0xA9, |rng, case| {
        let mut map = TensorMap::new();
        let n_tensors = 1 + rng.below(6);
        for t in 0..n_tensors {
            let name = format!("t{case}.{t}.w");
            let ndim = 1 + rng.below(3);
            let shape: Vec<usize> =
                (0..ndim).map(|_| 1 + rng.below(6)).collect();
            let count: usize = shape.iter().product();
            if rng.below(2) == 0 {
                map.insert(name, Tensor::F32 {
                    shape,
                    data: (0..count).map(|_| rng.normal() as f32).collect(),
                });
            } else {
                map.insert(name, Tensor::I32 {
                    shape,
                    data: (0..count)
                        .map(|_| rng.below(1000) as i32 - 500).collect(),
                });
            }
        }
        let path = std::env::temp_dir()
            .join(format!("prop_ltw_{case}.ltw"));
        write_ltw(&path, &map).map_err(|e| e.to_string())?;
        let buf = std::fs::read(&path).map_err(|e| e.to_string())?;
        let back = parse_ltw(&buf).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        prop_assert!(back == map, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random() {
    use latentllm::util::json::{parse, Value};
    fn random_value(rng: &mut latentllm::util::rng::Rng, depth: usize)
                    -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Value::Str(format!("s{}-\"esc\"\n{}", rng.below(100),
                                    rng.below(10))),
            4 => Value::Arr((0..rng.below(5))
                .map(|_| random_value(rng, depth - 1)).collect()),
            _ => Value::Obj((0..rng.below(5))
                .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                .collect()),
        }
    }
    run_cases("json-roundtrip", 40, 0xAA, |rng, _| {
        let v = random_value(rng, 3);
        let text = v.to_string_pretty();
        let back = parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(back == v, "roundtrip through {text}");
        Ok(())
    });
}

#[test]
fn prop_page_allocator_never_double_books_and_conserves_blocks() {
    // the paged KV allocator's safety contract under arbitrary churn:
    // no block is ever owned by two sequences (or a sequence and the
    // free list), releases never double-free, and free+alloc churn
    // conserves the pool exactly. Mixed byte-rates model dense and
    // latent sessions sharing one pool.
    use latentllm::coordinator::pages::PageAllocator;
    run_cases("page-allocator-churn", 30, 0xB7, |rng, _| {
        let block_bytes = 16 * (1 + rng.below(8)); // 16..128
        let total = (1 + rng.below(32)) * block_bytes; // 1..32 blocks
        let mut p = PageAllocator::new(total, block_bytes);
        let n_blocks = p.total_blocks();
        let rates = [4usize, 8, 16, 32, 64];
        let mut live: Vec<u64> = Vec::new();
        for op in 0..200 {
            match rng.below(10) {
                // admit (sometimes re-admitting a live id)
                0..=3 => {
                    let id = rng.below(12) as u64;
                    let rate = rates[rng.below(rates.len())];
                    let tokens = rng.below(24);
                    let free_before = p.free_blocks();
                    let had = p.blocks_of(id);
                    let ok = p.admit(id, tokens, rate);
                    let need = p.blocks_for(tokens, rate);
                    prop_assert!(ok == (need <= free_before + had),
                                 "op {op}: admit verdict wrong \
                                  (need {need}, free {free_before}, \
                                  held {had})");
                    if ok {
                        prop_assert!(p.blocks_of(id) == need,
                                     "op {op}: wrong block count");
                        if !live.contains(&id) {
                            live.push(id);
                        }
                    } else {
                        prop_assert!(p.blocks_of(id) == 0,
                                     "op {op}: failed admit must \
                                      deregister");
                        live.retain(|&l| l != id);
                    }
                }
                // extend a live sequence
                4..=6 => {
                    if let Some(&id) = live.get(rng.below(live.len()
                                                          .max(1))) {
                        let before = (p.tokens_of(id), p.blocks_of(id));
                        let ok = p.extend(id);
                        if ok {
                            prop_assert!(p.tokens_of(id) == before.0 + 1,
                                         "op {op}: extend must add one \
                                          token");
                        } else {
                            prop_assert!(
                                (p.tokens_of(id), p.blocks_of(id))
                                    == before,
                                "op {op}: refused extend must change \
                                 nothing");
                        }
                    }
                }
                // release (sometimes an unknown/already-released id —
                // must be a no-op, never a double-free)
                _ => {
                    let id = rng.below(16) as u64;
                    let others: usize = live.iter()
                        .filter(|&&l| l != id)
                        .map(|&l| p.blocks_of(l))
                        .sum();
                    p.release(id);
                    p.release(id); // idempotent by contract
                    live.retain(|&l| l != id);
                    prop_assert!(p.used_blocks() == others,
                                 "op {op}: release must return exactly \
                                  this sequence's blocks");
                }
            }
            // the global audit after EVERY operation
            p.check_invariants().map_err(|e| format!("op {op}: {e}"))?;
            let held: usize = live.iter().map(|&l| p.blocks_of(l)).sum();
            prop_assert!(held == p.used_blocks(),
                         "op {op}: live set and allocator disagree");
            prop_assert!(p.free_blocks() + p.used_blocks() == n_blocks,
                         "op {op}: churn must conserve total blocks");
        }
        Ok(())
    });
}

#[test]
fn prop_prefix_cache_churn_keeps_invariants_and_bits() {
    // the refcounted prefix cache under random admit / hit / donate /
    // extend / release / kill-switch churn: the allocator invariants
    // (refcounts, cached-free bookkeeping, block conservation) hold
    // after every op, a writer never appends into a block someone else
    // still references, and every snapshot the cache serves —
    // including resurrected cached-free blocks — is bit-identical to
    // what its donor stored.
    use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
    use latentllm::runtime::decode::{LayerCache, PrefixSnapshot};
    use std::collections::HashMap;

    // one dense layer whose rows are a pure function of the token ids,
    // so any served snapshot can be checked against a rebuild
    fn snap_for(tokens: &[i32], d: usize) -> PrefixSnapshot {
        let n = tokens.len();
        PrefixSnapshot {
            tokens: n,
            layers: vec![LayerCache::Dense {
                k: Matrix::from_fn(n, d, |r, c| {
                    tokens[r] as f64 + c as f64
                }),
                v: Matrix::from_fn(n, d, |r, _| tokens[r] as f64 * 0.5),
            }],
        }
    }

    run_cases("prefix-cache-churn", 25, 0xB8, |rng, _| {
        let d = 4 + 2 * rng.below(4); // dense layer width 4..10
        let bt = 2 + rng.below(3); // 2..4 tokens per block
        let blocks = 4 + rng.below(12); // 4..15 block pool
        let bpt = 2 * d * 2; // 1 layer at 2 B/element
        let mut m = KvCacheManager::with_block_tokens(
            CacheKind::Dense { d }, 1, 2, blocks * bt * bpt, bt);
        prop_assert!(m.bytes_per_token() == bpt, "rate setup");
        let off_rate = bpt * 2;
        // prompts drawn from a tiny alphabet behind a shared head, so
        // chains genuinely collide, extend and diverge across ops
        let head: Vec<i32> = (0..2 * bt as i32).map(|i| i % 5).collect();
        let mut feeds: HashMap<u64, Vec<i32>> = HashMap::new();
        for op in 0..150 {
            let id = rng.below(8) as u64;
            match rng.below(12) {
                // admit through the cache at the nominal rate: a served
                // hit must be bit-identical to a rebuild from its tokens
                0..=3 => {
                    let mut feed =
                        head[..rng.below(head.len()) + 1].to_vec();
                    for _ in 0..rng.below(2 * bt) {
                        feed.push(rng.below(5) as i32);
                    }
                    let (ok, hit) = m.admit_prefixed(id, &feed, bpt);
                    if let Some(h) = hit {
                        prop_assert!(ok, "op {op}: hit without admission");
                        prop_assert!(h.tokens < feed.len(),
                                     "op {op}: cap must leave ≥ 1 \
                                      live token");
                        let snap = PrefixSnapshot::concat(&h.snaps)
                            .map_err(|e| format!("op {op}: {e:#}"))?;
                        prop_assert!(snap.tokens == h.tokens,
                                     "op {op}: hit token count");
                        prop_assert!(
                            snap == snap_for(&feed[..h.tokens], d),
                            "op {op}: served rows differ from what \
                             the donor stored");
                    }
                    if ok {
                        feeds.insert(id, feed);
                    } else {
                        feeds.remove(&id);
                    }
                }
                // off-rate admission: rows may be served, physical
                // blocks must never be shared (token↔block misalignment)
                4 => {
                    let feed = head.clone();
                    let (ok, _) = m.admit_prefixed(id, &feed, off_rate);
                    if ok {
                        if let Some(bs) = m.pages().block_ids(id) {
                            for &b in bs {
                                prop_assert!(
                                    m.pages().refcount_of(b) == 1,
                                    "op {op}: off-rate session shares \
                                     block {b}");
                            }
                        }
                        feeds.insert(id, feed);
                    } else {
                        feeds.remove(&id);
                    }
                }
                // donate a live sequence's full prompt blocks
                // (idempotent; internally refused for off-rate holders)
                5..=6 => {
                    if let Some(feed) = feeds.get(&id).cloned() {
                        m.donate_prefix(id, &feed, &snap_for(&feed, d));
                    }
                }
                // grow: the writer's tail block must be private —
                // copy-on-write means never appending into a block
                // someone else still references
                7..=9 => {
                    if m.try_extend(id) {
                        let last = m.pages().block_ids(id)
                            .and_then(|bs| bs.last().copied());
                        if let Some(b) = last {
                            prop_assert!(m.pages().refcount_of(b) == 1,
                                         "op {op}: writer aliases \
                                          shared block {b}");
                        }
                        if let Some(f) = feeds.get_mut(&id) {
                            f.push(0);
                        }
                    }
                }
                // kill switch round-trip under load (rare)
                10 => {
                    if rng.below(8) == 0 {
                        m.set_prefix_cache(false);
                        prop_assert!(
                            m.pages().cached_free_blocks() == 0,
                            "op {op}: off must unpark every block");
                        m.set_prefix_cache(true);
                    }
                }
                // release — idempotent, unknown ids welcome
                _ => {
                    m.release(id);
                    m.release(id);
                    feeds.remove(&id);
                }
            }
            m.pages().check_invariants()
                .map_err(|e| format!("op {op}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_fused_step_bit_identical_under_session_churn() {
    // the fused decode step under arbitrary interleavings of
    // insert / step_many / single-step / remove over a
    // [`BatchedDecodeState`]: a fused state and a kill-switched state
    // driven by the identical op sequence (mixed prompt lengths, slot
    // reuse, capacity overruns) must return bit-identical logits — and
    // identical error verdicts — at every step, on the dense and the
    // latent program, across all three weight layouts.
    use latentllm::data::synth::write_test_artifacts;
    use latentllm::model::config::MiniConfig;
    use latentllm::model::Weights;
    use latentllm::runtime::decode::BatchedDecodeState;
    use latentllm::runtime::Engine;
    use latentllm::Layout;

    const CFG: MiniConfig = MiniConfig {
        name: "fuseprop", vocab: 48, d: 16, n_layers: 2, n_heads: 2,
        d_i: 32, max_len: 32,
    };
    let dir = std::env::temp_dir()
        .join(format!("latentllm_prop_fused_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let tag = write_test_artifacts(&dir, &CFG, 17).unwrap();
    let engine = Engine::new(&dir).unwrap();
    let dense = Weights::load(
        dir.join(format!("model_{}.ltw", CFG.name))).unwrap();
    let latent = Weights::load(
        dir.join(format!("latent_model_{tag}.ltw"))).unwrap();
    let mut cases: Vec<(String, Weights)> = Vec::new();
    for (program, base) in [(format!("step_{}", CFG.name), &dense),
                            (format!("latent_step_{tag}"), &latent)] {
        for layout in [Layout::DenseF64, Layout::PackedF32,
                       Layout::QuantI8] {
            let w = if layout == Layout::DenseF64 {
                base.clone()
            } else {
                base.repack(layout, 16).unwrap()
            };
            cases.push((program.clone(), w));
        }
    }

    run_cases("fused-step-churn", 6, 0xB9, |rng, case| {
        let (program, weights) = &cases[case % cases.len()];
        let prog = engine.program(program).map_err(|e| e.to_string())?;
        let mut fused = BatchedDecodeState::new();
        let mut plain = BatchedDecodeState::new();
        plain.set_fused(false);
        let mut live: Vec<usize> = Vec::new();
        let mut next_seq = 0u64;
        let mut wide_batches = 0u64;
        for op in 0..40 {
            match rng.below(8) {
                // open + prefill a fresh sequence in both states
                0..=2 if live.len() < 5 => {
                    let plen = 1 + rng.below(6);
                    let prompt: Vec<i32> = (0..plen)
                        .map(|_| rng.below(CFG.vocab) as i32)
                        .collect();
                    let mut sa = prog.decode_session(weights)
                        .map_err(|e| e.to_string())?;
                    let mut sb = prog.decode_session(weights)
                        .map_err(|e| e.to_string())?;
                    let la = sa.prefill(&prompt)
                        .map_err(|e| e.to_string())?;
                    let lb = sb.prefill(&prompt)
                        .map_err(|e| e.to_string())?;
                    prop_assert!(la == lb, "op {op}: prefill diverged");
                    let slot = fused.insert(next_seq, sa);
                    prop_assert!(plain.insert(next_seq, sb) == slot,
                                 "op {op}: slot allocation diverged");
                    live.push(slot);
                    next_seq += 1;
                }
                // retire a random sequence from both states
                3 if !live.is_empty() => {
                    let slot = live.swap_remove(rng.below(live.len()));
                    prop_assert!(fused.remove(slot) == plain.remove(slot),
                                 "op {op}: remove diverged");
                }
                // one mixed batch over every live slot (the fused shape)
                _ if !live.is_empty() => {
                    let steps: Vec<(usize, i32)> = live.iter()
                        .map(|&s| (s, rng.below(CFG.vocab) as i32))
                        .collect();
                    if steps.len() >= 2 {
                        wide_batches += 1;
                    }
                    let a = fused.step_many(&steps);
                    let b = plain.step_many(&steps);
                    for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
                        match (ra, rb) {
                            (Ok(x), Ok(y)) => prop_assert!(
                                x == y,
                                "op {op}: row {i} logits diverged"),
                            (Err(_), Err(_)) => {}
                            _ => prop_assert!(
                                false,
                                "op {op}: row {i} verdicts diverged \
                                 (fused ok={} plain ok={})",
                                ra.is_ok(), rb.is_ok()),
                        }
                    }
                }
                _ => {}
            }
        }
        // the churn must actually exercise the fused path (capacity
        // overruns can demote SOME wide batches, never all of them)
        if wide_batches > 0 {
            prop_assert!(fused.fused_stats().0 >= 1,
                         "no wide batch fused ({wide_batches} seen)");
        }
        prop_assert!(plain.fused_stats() == (0, 0),
                     "kill-switched state must never fuse");
        Ok(())
    });
    std::fs::remove_dir_all(&dir).ok();
}
