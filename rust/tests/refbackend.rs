//! Reference-backend tests: no artifacts directory needed — each test
//! synthesizes a tiny manifest (+ in-memory weights) in a tempdir and runs
//! the full Engine / eval / coordinator stack on [`RefBackend`].

use std::collections::BTreeMap;
use std::path::PathBuf;

use latentllm::compress::pipeline::tests_support::random_weights;
use latentllm::coordinator::batcher::BatcherConfig;
use latentllm::coordinator::kvcache::{CacheKind, KvCacheManager};
use latentllm::coordinator::router::{ModelVariant, Policy, Router};
use latentllm::coordinator::server::{Drain, ScoreParams, Server,
                                     ServerConfig};
use latentllm::data::Corpus;
use latentllm::eval;
use latentllm::model::config::MiniConfig;
use latentllm::model::io::{Tensor, TensorMap};
use latentllm::model::Weights;
use latentllm::runtime::Engine;
use latentllm::util::json::Value;
use latentllm::util::rng::Rng;

const TINY: MiniConfig = MiniConfig {
    name: "tiny", vocab: 40, d: 16, n_layers: 2, n_heads: 2,
    d_i: 32, max_len: 24,
};
const SEQ: usize = 12;
const BATCH: usize = 4;

fn num(v: usize) -> Value {
    Value::Num(v as f64)
}

fn obj(pairs: Vec<(String, Value)>) -> Value {
    Value::Obj(pairs.into_iter().collect::<BTreeMap<String, Value>>())
}

fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

fn lm_config_json(cfg: &MiniConfig) -> Value {
    obj(vec![
        ("name".to_string(), s(cfg.name)),
        ("vocab".to_string(), num(cfg.vocab)),
        ("d".to_string(), num(cfg.d)),
        ("n_layers".to_string(), num(cfg.n_layers)),
        ("n_heads".to_string(), num(cfg.n_heads)),
        ("d_i".to_string(), num(cfg.d_i)),
        ("max_len".to_string(), num(cfg.max_len)),
    ])
}

fn str_list(names: &[&str]) -> Value {
    Value::Arr(names.iter().map(|n| s(n)).collect())
}

/// Write a synthetic manifest.json for the tiny model (score/step/latent/
/// mm program table) into a fresh tempdir; returns the artifacts path.
fn synth_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("latentllm_refbackend_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut score_order = vec!["tokens".to_string()];
    score_order.extend(TINY.param_names());
    let mut step_order = vec!["tokens".to_string(), "lens".to_string()];
    step_order.extend(TINY.param_names());
    let as_arr = |v: &[String]| {
        Value::Arr(v.iter().map(|n| s(n)).collect())
    };

    let programs = obj(vec![
        ("score_tiny".to_string(), as_arr(&score_order)),
        ("step_tiny".to_string(), as_arr(&step_order)),
        ("latent_score_tinytag".to_string(), str_list(&["tokens"])),
        ("latent_step_tinytag".to_string(),
         str_list(&["tokens", "lens"])),
        ("mm_score_mini".to_string(), str_list(&["images", "tokens"])),
    ]);
    let models = obj(vec![(
        "tiny".to_string(),
        obj(vec![("config".to_string(), lm_config_json(&TINY))]),
    )]);
    let latent_demo = obj(vec![
        ("tag".to_string(), s("tinytag")),
        ("model".to_string(), s("tiny")),
    ]);
    let mm_lm = MiniConfig {
        name: "mm-lm", vocab: 32, d: 8, n_layers: 1, n_heads: 2,
        d_i: 16, max_len: 24,
    };
    let mm = obj(vec![
        ("config".to_string(), obj(vec![
            ("name".to_string(), s("mini")),
            ("lm".to_string(), lm_config_json(&mm_lm)),
            ("vision".to_string(), obj(vec![
                ("img".to_string(), num(16)),
                ("patch".to_string(), num(4)),
                ("d".to_string(), num(8)),
                ("n_layers".to_string(), num(1)),
                ("n_heads".to_string(), num(2)),
                ("d_i".to_string(), num(16)),
            ])),
            ("n_answers".to_string(), num(4)),
        ])),
        ("text_len".to_string(), num(6)),
    ]);
    let manifest = obj(vec![
        ("seq_len".to_string(), num(SEQ)),
        ("score_batch".to_string(), num(BATCH)),
        ("vocab".to_string(), num(TINY.vocab)),
        ("programs".to_string(), programs),
        ("models".to_string(), models),
        ("latent_demo".to_string(), latent_demo),
        ("mm".to_string(), mm),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty())
        .unwrap();
    dir
}

fn rand_t(rng: &mut Rng, shape: &[usize], scale: f64) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::F32 {
        shape: shape.to_vec(),
        data: (0..n).map(|_| (rng.normal() * scale) as f32).collect(),
    }
}

fn const_t(shape: &[usize], v: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::F32 { shape: shape.to_vec(), data: vec![v; n] }
}

fn corpus(n: usize) -> Corpus {
    let mut rng = Rng::new(7);
    Corpus {
        name: "synth".to_string(),
        tokens: (0..n).map(|_| rng.below(TINY.vocab) as i32).collect(),
    }
}

#[test]
fn engine_program_cache_shares_instances() {
    let art = synth_artifacts("cache");
    let engine = Engine::new(&art).unwrap();
    assert_eq!(engine.backend_name(), "ref");
    assert_eq!(engine.cached_programs(), 0);
    let p1 = engine.program("score_tiny").unwrap();
    let p2 = engine.program("score_tiny").unwrap();
    assert!(std::sync::Arc::ptr_eq(&p1, &p2), "cache must share programs");
    assert_eq!(engine.cached_programs(), 1);
    let p3 = engine.program("step_tiny").unwrap();
    assert_eq!(p3.param_order[..2], ["tokens".to_string(),
                                     "lens".to_string()]);
    assert_eq!(engine.cached_programs(), 2);
    assert_eq!(Engine::leading_count(&p3.param_order), 2);
    assert!(engine.program("score_nonexistent").is_err());
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn zero_weights_score_uniform_perplexity() {
    // all-zero weights ⇒ uniform logits ⇒ ppl == vocab exactly: an
    // analytic anchor through Engine + eval::perplexity on RefBackend.
    let art = synth_artifacts("uniform");
    let engine = Engine::new(&art).unwrap();
    let mut map = TensorMap::new();
    let shapes_src = random_weights(&TINY, 3);
    for name in shapes_src.names() {
        let t = shapes_src.tensor(name).unwrap();
        let fill = if name.ends_with(".g") { 1.0 } else { 0.0 };
        map.insert(name.clone(), const_t(t.shape(), fill));
    }
    let weights = Weights::new(map);
    let r = eval::perplexity(&engine, "score_tiny", &weights, &corpus(600),
                             BATCH, SEQ, 3).unwrap();
    assert!((r.ppl - TINY.vocab as f64).abs() < 1e-3,
            "uniform ppl {} vs vocab {}", r.ppl, TINY.vocab);
    assert_eq!(r.n_sequences, 3 * BATCH);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn score_and_generate_end_to_end() {
    let art = synth_artifacts("e2e");
    let engine = Engine::new(&art).unwrap();
    let weights = random_weights(&TINY, 11);
    let r = eval::perplexity(&engine, "score_tiny", &weights, &corpus(600),
                             BATCH, SEQ, 2).unwrap();
    assert!(r.ppl.is_finite() && r.ppl > 1.0, "ppl {}", r.ppl);

    let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![4, 5, 6, 7]];
    let opts = eval::GenerateOpts { max_new: 4, temperature: 0.0, seed: 3,
                                    ..Default::default() };
    let res = eval::generate(&engine, "step_tiny", &weights, &prompts,
                             BATCH, SEQ, TINY.vocab, &opts).unwrap();
    assert_eq!(res.sequences.len(), 2);
    assert_eq!(res.sequences[0].len(), 3 + 4);
    assert_eq!(res.sequences[1].len(), 4 + 4);
    assert_eq!(res.tokens_generated, 2 * 4);
    for seq in &res.sequences {
        assert!(seq.iter().all(|&t| (0..TINY.vocab as i32).contains(&t)));
    }
    // greedy decode is deterministic
    let res2 = eval::generate(&engine, "step_tiny", &weights, &prompts,
                              BATCH, SEQ, TINY.vocab, &opts).unwrap();
    assert_eq!(res.sequences, res2.sequences);
    std::fs::remove_dir_all(&art).ok();
}

/// Dense tiny-model variant over random weights (server test fixture).
fn tiny_variant(seed: u64) -> ModelVariant {
    ModelVariant {
        name: "dense".to_string(),
        score_program: "score_tiny".to_string(),
        step_program: "step_tiny".to_string(),
        weights: std::sync::Arc::new(random_weights(&TINY, seed)),
        cache: KvCacheManager::new(CacheKind::Dense { d: TINY.d },
                                   TINY.n_layers, 2, 8 << 20),
    }
}

#[test]
fn server_pads_short_requests_through_batcher() {
    // coordinator::batcher padding path: submit more (short) requests
    // than one flush holds; execute_batch pads each to [program_batch,
    // seq_len] before the RefBackend scoring program runs.
    let art = synth_artifacts("serve");
    let server = Server::start(
        art.clone(),
        Router::new(vec![tiny_variant(21)], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 3,
                max_wait: std::time::Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 2,
            sched: None,
            trace: true,
        })
        .expect("server start");
    assert_eq!(server.live_workers(), 2);
    // ragged, shorter-than-seq_len requests exercise the padding fill
    let reqs: Vec<Vec<i32>> = (0..7)
        .map(|i| (0..(3 + i % 4)).map(|j| ((i * 5 + j) % 40) as i32)
            .collect())
        .collect();
    let rxs: Vec<_> = reqs.into_iter()
        .map(|tokens| server.submit_score(ScoreParams { tokens })
            .expect("submit"))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60))
            .expect("response");
        assert!(resp.nll().is_finite(), "padded request must score");
    }
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("requests"), 7);
    assert_eq!(m.counter("batch_errors"), 0);
    assert!(m.counter("batches") >= 3, "max_batch=3 forces ≥3 flushes");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn overflow_flush_splits_instead_of_nan() {
    // regression: a batcher flush larger than program_batch used to pack
    // only the first `program_batch` requests but reply to all of them —
    // the overflow silently got nll = NaN. The server must now split the
    // flush into program-shaped executions and score every request.
    let art = synth_artifacts("overflow");
    let server = Server::start(
        art.clone(),
        Router::new(vec![tiny_variant(22)], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                // misconfigured: twice the program batch
                max_batch: 2 * BATCH,
                max_wait: std::time::Duration::from_millis(500),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 1,
            sched: None,
            trace: true,
        })
        .expect("server start");
    // submit 2×BATCH requests quickly so one flush exceeds program_batch
    let rxs: Vec<_> = (0..2 * BATCH)
        .map(|i| server.submit_score(ScoreParams {
            tokens: (0..SEQ).map(|j| ((i * 7 + j) % 40) as i32).collect(),
        }).expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60))
            .expect("response");
        assert!(resp.error().is_none(), "request {i}: {:?}", resp.error());
        assert!(resp.nll().is_finite(),
                "request {i} got NaN — overflow entries must be scored");
    }
    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("requests"), 2 * BATCH as u64);
    assert_eq!(m.counter("batch_errors"), 0);
    assert!(m.counter("batch_overflow") >= 1,
            "oversized flush must be counted");
    assert!(m.counter("batches") >= 2, "split must execute ≥2 programs");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn invalid_requests_get_error_responses_not_a_dead_worker() {
    // regression: an empty token list used to index toks[0] and panic the
    // serve thread; every later request then hung. Now empty (and
    // over-long) requests get an error-carrying response and the worker
    // keeps serving.
    let art = synth_artifacts("invalid");
    let server = Server::start(
        art.clone(),
        Router::new(vec![tiny_variant(23)], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: BATCH,
                max_wait: std::time::Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 1,
            sched: None,
            trace: true,
        })
        .expect("server start");
    let timeout = std::time::Duration::from_secs(60);

    let empty = server.submit_score(ScoreParams { tokens: vec![] })
        .expect("submit");
    let resp = empty.recv_timeout(timeout).expect("error response");
    assert!(resp.error().is_some(), "empty request must carry an error");
    assert!(resp.nll().is_nan());

    let too_long = server.submit_score(ScoreParams {
        tokens: vec![1; SEQ + 5],
    }).expect("submit");
    let resp = too_long.recv_timeout(timeout).expect("error response");
    assert!(resp.error().is_some(),
            "over-long request must carry an error");

    // the worker must still be alive and scoring
    let ok = server.submit_score(ScoreParams {
        tokens: vec![3, 5, 7],
    }).expect("submit");
    let resp = ok.recv_timeout(timeout).expect("worker survived");
    assert!(resp.error().is_none());
    assert!(resp.nll().is_finite());

    let m = server.shutdown(Drain::Graceful);
    assert_eq!(m.counter("request_errors"), 2);
    assert_eq!(m.counter("batch_errors"), 0);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn failed_batch_execution_replies_with_errors() {
    // a variant pointing at a program the manifest doesn't have: every
    // request in the batch must get an error-carrying response (not a
    // dropped reply channel) and the worker must count a batch_error
    let art = synth_artifacts("badprog");
    let variant = ModelVariant {
        name: "broken".to_string(),
        score_program: "score_nonexistent".to_string(),
        step_program: "step_nonexistent".to_string(),
        weights: std::sync::Arc::new(random_weights(&TINY, 25)),
        cache: KvCacheManager::new(CacheKind::Dense { d: TINY.d },
                                   TINY.n_layers, 2, 8 << 20),
    };
    let server = Server::start(
        art.clone(),
        Router::new(vec![variant], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 2,
                max_wait: std::time::Duration::from_millis(1),
            },
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 1,
            sched: None,
            trace: true,
        })
        .expect("server start (engine init itself is fine)");
    let rxs: Vec<_> = (0..3u64)
        .map(|_| server.submit_score(ScoreParams {
            tokens: vec![1, 2, 3],
        }).expect("submit"))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60))
            .expect("error response, not a dropped channel");
        assert!(resp.error().is_some());
        assert!(resp.error().unwrap().contains("batch execution failed"));
        assert!(resp.nll().is_nan());
    }
    let m = server.shutdown(Drain::Graceful);
    assert!(m.counter("batch_errors") >= 1);
    assert_eq!(m.counter("batches"), 0, "nothing actually executed");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn failed_engine_init_surfaces_from_start() {
    // regression: Engine::new failing in the worker used to leave a dead
    // server whose submit() panicked the *caller*. start() must return
    // the init error instead.
    let missing = std::env::temp_dir()
        .join(format!("latentllm_refbackend_no_such_artifacts_{}",
                      std::process::id()));
    std::fs::remove_dir_all(&missing).ok();
    let res = Server::start(
        missing,
        Router::new(vec![tiny_variant(24)], Policy::RoundRobin),
        ServerConfig {
            batcher: BatcherConfig::default(),
            policy: Policy::RoundRobin,
            program_batch: BATCH,
            seq_len: SEQ,
            workers: 3,
            sched: None,
            trace: true,
        });
    let err = match res {
        Ok(_) => panic!("start must fail without a manifest"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("engine init"), "unexpected error chain: {err}");
}

/// Random latent/MLA weight set in the python `latent_shapes` layout.
fn random_latent_weights(seed: u64) -> Weights {
    let (d, h, di) = (TINY.d, TINY.n_heads, TINY.d_i);
    let dh = d / h;
    let (rq, rk, rv, ro, ru, rd) = (5, 5, 4, 4, 6, 6);
    let mut rng = Rng::new(seed);
    let sc = 0.5 / (d as f64).sqrt();
    let mut map = TensorMap::new();
    map.insert("tok_emb".to_string(),
               rand_t(&mut rng, &[TINY.vocab, d], sc));
    map.insert("pos_emb".to_string(),
               rand_t(&mut rng, &[TINY.max_len, d], sc));
    for i in 0..TINY.n_layers {
        let p = format!("layers.{i}.");
        map.insert(format!("{p}ln1.g"), const_t(&[d], 1.0));
        map.insert(format!("{p}ln1.b"), const_t(&[d], 0.0));
        map.insert(format!("{p}attn.aq"), rand_t(&mut rng, &[rq, d], sc));
        map.insert(format!("{p}attn.bq_heads"),
                   rand_t(&mut rng, &[h, dh, rq], sc));
        map.insert(format!("{p}attn.bq"), const_t(&[d], 0.01));
        map.insert(format!("{p}attn.ak"), rand_t(&mut rng, &[rk, d], sc));
        map.insert(format!("{p}attn.bk_heads"),
                   rand_t(&mut rng, &[h, dh, rk], sc));
        map.insert(format!("{p}attn.bk"), const_t(&[d], 0.01));
        map.insert(format!("{p}attn.av"), rand_t(&mut rng, &[rv, d], sc));
        map.insert(format!("{p}attn.bv_heads"),
                   rand_t(&mut rng, &[h, dh, rv], sc));
        map.insert(format!("{p}attn.bv"), const_t(&[d], 0.01));
        map.insert(format!("{p}attn.ao_heads"),
                   rand_t(&mut rng, &[ro, h * dh], sc));
        map.insert(format!("{p}attn.bo_mat"), rand_t(&mut rng, &[d, ro], sc));
        map.insert(format!("{p}attn.bo"), const_t(&[d], 0.0));
        map.insert(format!("{p}ln2.g"), const_t(&[d], 1.0));
        map.insert(format!("{p}ln2.b"), const_t(&[d], 0.0));
        map.insert(format!("{p}mlp.au"), rand_t(&mut rng, &[ru, d], sc));
        map.insert(format!("{p}mlp.bu_mat"),
                   rand_t(&mut rng, &[di, ru], sc));
        map.insert(format!("{p}mlp.bu"), const_t(&[di], 0.01));
        map.insert(format!("{p}mlp.ad"), rand_t(&mut rng, &[rd, di], sc));
        map.insert(format!("{p}mlp.bd_mat"),
                   rand_t(&mut rng, &[d, rd], sc));
        map.insert(format!("{p}mlp.bd"), const_t(&[d], 0.0));
    }
    map.insert("lnf.g".to_string(), const_t(&[d], 1.0));
    map.insert("lnf.b".to_string(), const_t(&[d], 0.0));
    Weights::new(map)
}

#[test]
fn latent_mla_programs_run_end_to_end() {
    let art = synth_artifacts("latent");
    let engine = Engine::new(&art).unwrap();
    let weights = random_latent_weights(31);
    let r = eval::perplexity(&engine, "latent_score_tinytag", &weights,
                             &corpus(600), BATCH, SEQ, 2).unwrap();
    assert!(r.ppl.is_finite() && r.ppl > 1.0, "latent ppl {}", r.ppl);

    let prompts: Vec<Vec<i32>> = vec![vec![2, 4, 6]];
    let opts = eval::GenerateOpts { max_new: 3, temperature: 0.0, seed: 5,
                                    ..Default::default() };
    let res = eval::generate(&engine, "latent_step_tinytag", &weights,
                             &prompts, BATCH, SEQ, TINY.vocab, &opts)
        .unwrap();
    assert_eq!(res.sequences[0].len(), 3 + 3);
    // unknown latent tags must be rejected, not misinterpreted
    assert!(engine.program("latent_score_othertag").is_err());
    std::fs::remove_dir_all(&art).ok();
}

/// Random llava-mini-style weight set (vit tower + projector + lm tower).
fn random_mm_weights(seed: u64) -> Weights {
    let vit_cfg = MiniConfig {
        name: "mm-vit", vocab: 4, d: 8, n_layers: 1, n_heads: 2,
        d_i: 16, max_len: 16,
    };
    let lm_cfg = MiniConfig {
        name: "mm-lm", vocab: 32, d: 8, n_layers: 1, n_heads: 2,
        d_i: 16, max_len: 24,
    };
    let mut rng = Rng::new(seed);
    let mut map = TensorMap::new();
    map.insert("vit.patch.w".to_string(), rand_t(&mut rng, &[8, 16], 0.2));
    map.insert("vit.patch.b".to_string(), const_t(&[8], 0.0));
    map.insert("vit.pos".to_string(), rand_t(&mut rng, &[16, 8], 0.02));
    let vit = random_weights(&vit_cfg, seed + 1);
    for name in vit.names() {
        if name.starts_with("layers.") {
            map.insert(format!("vit.{name}"), vit.tensor(name).unwrap()
                .clone());
        }
    }
    map.insert("vit.lnf.g".to_string(), const_t(&[8], 1.0));
    map.insert("vit.lnf.b".to_string(), const_t(&[8], 0.0));
    map.insert("proj.w".to_string(), rand_t(&mut rng, &[8, 8], 0.3));
    map.insert("proj.b".to_string(), const_t(&[8], 0.0));
    let lm = random_weights(&lm_cfg, seed + 2);
    for name in lm.names() {
        map.insert(format!("lm.{name}"), lm.tensor(name).unwrap().clone());
    }
    map.insert("ans.w".to_string(), rand_t(&mut rng, &[4, 8], 0.3));
    map.insert("ans.b".to_string(), const_t(&[4], 0.0));
    Weights::new(map)
}

#[test]
fn multimodal_program_scores_batches() {
    let art = synth_artifacts("mm");
    let engine = Engine::new(&art).unwrap();
    let weights = random_mm_weights(41);
    let mut rng = Rng::new(9);
    let n = 5usize; // not a multiple of batch: exercises final-batch pad
    let text_len = 6usize;
    let mut data = TensorMap::new();
    data.insert("images".to_string(),
                rand_t(&mut rng, &[n, 16, 16], 1.0));
    data.insert("tokens".to_string(), Tensor::I32 {
        shape: vec![n, text_len],
        data: (0..n * text_len).map(|i| (i % 32) as i32).collect(),
    });
    data.insert("labels".to_string(), Tensor::I32 {
        shape: vec![n],
        data: (0..n).map(|i| (i % 4) as i32).collect(),
    });
    data.insert("cats".to_string(), Tensor::I32 {
        shape: vec![n, 3],
        data: (0..n * 3).map(|i| (i % 2) as i32).collect(),
    });
    let r = eval::evaluate_mm(&engine, "mm_score_mini", &weights, &data, 2)
        .unwrap();
    assert_eq!(r.n, n);
    assert!((0.0..=1.0).contains(&r.avg), "accuracy {}", r.avg);
    std::fs::remove_dir_all(&art).ok();
}
