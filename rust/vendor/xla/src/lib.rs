//! Offline type-gating stub for the `xla`/PJRT crate.
//!
//! This build environment has no XLA runtime, but the `pjrt` feature of
//! the `latentllm` crate must still *type-check* (`cargo check --features
//! pjrt`). This stub mirrors the API surface `runtime::pjrt` uses; every
//! entry point returns [`XlaError`] at runtime. Deploying against a real
//! PJRT requires swapping this path dependency for an actual xla crate
//! with the same surface.

use std::fmt;

/// Error type standing in for the real crate's error enum.
#[derive(Debug)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn unavailable(what: &str) -> Self {
        XlaError {
            msg: format!(
                "{what}: xla/PJRT runtime is not linked into this build \
                 (offline stub; see rust/vendor/xla)"
            ),
        }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types the marshalling layer supports.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal (stub: holds nothing).
#[derive(Debug, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(XlaError::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(XlaError::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L])
                                      -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: construction always fails so callers fall back).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
